//! Runtime-dispatched SIMD bodies for the fused tensor kernels.
//!
//! Every hot loop in [`crate::tensor`] (elastic pulls, push/weighted
//! means, q8/q4 (de)quantization) and the identity codec's byte path
//! routes through the dispatched entry points here.  Dispatch is decided
//! **once** per process (`AVX2` on x86_64, `NEON` on aarch64, scalar
//! everywhere else) and cached in an atomic; setting `EG_FORCE_SCALAR`
//! to any value other than `0`/empty pins the scalar path so CI can run
//! the suite on both sides of the dispatch.
//!
//! **Bit-identity contract.**  Each vector body performs, per element,
//! the *same* IEEE-754 operations in the *same* order as its `_scalar`
//! reference (exposed publicly so the property suite and
//! `benches/kernels.rs` can compare the two directly, without racing on
//! the global dispatch level):
//!
//! * element-wise kernels are lane-independent, so lane width cannot
//!   reorder anything — the only rule is **no FMA contraction** (a fused
//!   multiply-add rounds once where the scalar code rounds twice), hence
//!   every body uses separate mul/add intrinsics;
//! * the min/max fold under quantization is *not* lane-independent, so
//!   the scalar reference itself runs a fixed **8-lane virtual-stride**
//!   scheme (element `j` folds into accumulator `j % 8`, accumulators
//!   combine in lane order) with comparison predicates (`if v < acc`)
//!   rather than `f32::min` — deterministic for `±0.0` ties and
//!   NaN-skipping, and exactly the shape an 8-lane AVX2 register (or a
//!   NEON register pair) folds natively;
//! * the float→int step of quantization relies on the caller contract
//!   that `inv` is either `0` or `max_code / (hi - lo)` of the source
//!   chunk, under which `_mm256_cvttps_epi32`'s out-of-range sentinel
//!   (`i32::MIN`) and Rust's saturating `as i32` collapse to the same
//!   code after the `[0, max_code]` integer clamp (NaN → 0 either way).
//!
//! The golden-trajectory suite and the `prop_async_lockstep_*`
//! properties therefore see identical trajectories with dispatch active
//! or forced scalar; vectorization is observable only in
//! `BENCH_kernels.json`.

use std::sync::atomic::{AtomicU8, Ordering};

/// Which kernel bodies the process dispatches to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Level {
    /// Portable scalar loops (also the forced path under `EG_FORCE_SCALAR`).
    Scalar,
    /// 8 x f32 / 4 x f64 AVX2 bodies (x86_64, runtime-detected).
    Avx2,
    /// 4 x f32 / 2 x f64 NEON bodies (aarch64, runtime-detected).
    Neon,
}

/// 0 = undetected; else `Level as u8 + 1`.
static LEVEL: AtomicU8 = AtomicU8::new(0);

fn detect() -> Level {
    if std::env::var_os("EG_FORCE_SCALAR").is_some_and(|v| !v.is_empty() && v != "0") {
        return Level::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    if std::arch::is_x86_feature_detected!("avx2") {
        return Level::Avx2;
    }
    #[cfg(target_arch = "aarch64")]
    if std::arch::is_aarch64_feature_detected!("neon") {
        return Level::Neon;
    }
    Level::Scalar
}

/// The cached dispatch decision (detected on first use).
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        1 => Level::Scalar,
        2 => Level::Avx2,
        3 => Level::Neon,
        _ => {
            let l = detect();
            LEVEL.store(
                match l {
                    Level::Scalar => 1,
                    Level::Avx2 => 2,
                    Level::Neon => 3,
                },
                Ordering::Relaxed,
            );
            l
        }
    }
}

/// Human-readable dispatch label for bench output and reports.
pub fn active_name() -> &'static str {
    match level() {
        Level::Scalar => "scalar",
        Level::Avx2 => "avx2",
        Level::Neon => "neon",
    }
}

// ---------------------------------------------------------------------------
// dispatched entry points (each writes its match out so the cfg-gated
// arms stay greppable)
// ---------------------------------------------------------------------------

/// `dst[i] -= alpha * (a[i] - b[i])` — the elastic pull inner body.
#[inline]
pub fn sub_scaled_diff(dst: &mut [f32], a: &[f32], b: &[f32], alpha: f32) {
    debug_assert!(dst.len() == a.len() && dst.len() == b.len());
    match level() {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { avx2::sub_scaled_diff(dst, a, b, alpha) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { neon::sub_scaled_diff(dst, a, b, alpha) },
        _ => sub_scaled_diff_scalar(dst, a, b, alpha),
    }
}

/// `dst[i] = 0.5 * (a[i] + b[i])`.
#[inline]
pub fn average(dst: &mut [f32], a: &[f32], b: &[f32]) {
    debug_assert!(dst.len() == a.len() && dst.len() == b.len());
    match level() {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { avx2::average(dst, a, b) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { neon::average(dst, a, b) },
        _ => average_scalar(dst, a, b),
    }
}

/// `dst[i] = 0.5 * (dst[i] + y[i])` — in-place averaging.
#[inline]
pub fn average_in(dst: &mut [f32], y: &[f32]) {
    debug_assert_eq!(dst.len(), y.len());
    match level() {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { avx2::average_in(dst, y) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { neon::average_in(dst, y) },
        _ => average_in_scalar(dst, y),
    }
}

/// `acc[i] += x[i]` — the push-mean accumulate body.
#[inline]
pub fn add_assign(acc: &mut [f32], x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    match level() {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { avx2::add_assign(acc, x) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { neon::add_assign(acc, x) },
        _ => add_assign_scalar(acc, x),
    }
}

/// `dst[i] = acc[i] * inv` — the push-mean scale-out body.
#[inline]
pub fn scale_into(dst: &mut [f32], acc: &[f32], inv: f32) {
    debug_assert_eq!(dst.len(), acc.len());
    match level() {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { avx2::scale_into(dst, acc, inv) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { neon::scale_into(dst, acc, inv) },
        _ => scale_into_scalar(dst, acc, inv),
    }
}

/// `acc[i] = x[i] as f64 * w` — push-sum f64 accumulator init.
#[inline]
pub fn wacc_set(acc: &mut [f64], x: &[f32], w: f64) {
    debug_assert_eq!(acc.len(), x.len());
    match level() {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { avx2::wacc_set(acc, x, w) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { neon::wacc_set(acc, x, w) },
        _ => wacc_set_scalar(acc, x, w),
    }
}

/// `acc[i] += x[i] as f64 * w` — push-sum f64 accumulate.
#[inline]
pub fn wacc_add(acc: &mut [f64], x: &[f32], w: f64) {
    debug_assert_eq!(acc.len(), x.len());
    match level() {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { avx2::wacc_add(acc, x, w) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { neon::wacc_add(acc, x, w) },
        _ => wacc_add_scalar(acc, x, w),
    }
}

/// `dst[i] = (acc[i] * inv) as f32` — push-sum f64→f32 store.
#[inline]
pub fn store_scaled(dst: &mut [f32], acc: &[f64], inv: f64) {
    debug_assert_eq!(dst.len(), acc.len());
    match level() {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { avx2::store_scaled(dst, acc, inv) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { neon::store_scaled(dst, acc, inv) },
        _ => store_scaled_scalar(dst, acc, inv),
    }
}

/// Strided-8 `(min, max)` fold (NaN-skipping; `±0.0` ties keep the
/// incumbent).  Returns `(INFINITY, NEG_INFINITY)` for an empty or
/// all-NaN input.
#[inline]
pub fn minmax(src: &[f32]) -> (f32, f32) {
    match level() {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { avx2::minmax(src) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { neon::minmax(src) },
        _ => minmax_scalar(src),
    }
}

/// `out[i] = clamp(((src[i] - lo) * inv + 0.5) as i32, 0, max_code)` —
/// the affine quantization body.  Contract: `inv` is `0` or
/// `max_code as f32 / (hi - lo)` with `(lo, hi) = minmax(src)`; under it
/// the vector and scalar paths are bit-identical (see module docs).
#[inline]
pub fn quant_codes(src: &[f32], lo: f32, inv: f32, max_code: i32, out: &mut [u8]) {
    debug_assert_eq!(src.len(), out.len());
    match level() {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { avx2::quant_codes(src, lo, inv, max_code, out) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { neon::quant_codes(src, lo, inv, max_code, out) },
        _ => quant_codes_scalar(src, lo, inv, max_code, out),
    }
}

/// `dst[i] = lo + codes[i] as f32 * scale` — the dequantization body.
#[inline]
pub fn dequant_codes(codes: &[u8], lo: f32, scale: f32, dst: &mut [f32]) {
    debug_assert_eq!(codes.len(), dst.len());
    match level() {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { avx2::dequant_codes(codes, lo, scale, dst) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { neon::dequant_codes(codes, lo, scale, dst) },
        _ => dequant_codes_scalar(codes, lo, scale, dst),
    }
}

// ---------------------------------------------------------------------------
// identity-codec byte paths
// ---------------------------------------------------------------------------

/// Serialize `src` as little-endian f32 bytes into `out` (cleared
/// first).  On little-endian targets this is one bulk copy — the
/// in-memory representation *is* the wire format; the byte-wise loop is
/// the big-endian fallback and the semantic reference.
pub fn f32s_to_le_bytes(src: &[f32], out: &mut Vec<u8>) {
    out.clear();
    out.reserve(4 * src.len());
    if cfg!(target_endian = "little") {
        // f32 has no padding and 4-byte layout; viewing the slice as raw
        // bytes is sound and, on LE, already the wire encoding
        let bytes =
            unsafe { std::slice::from_raw_parts(src.as_ptr() as *const u8, 4 * src.len()) };
        out.extend_from_slice(bytes);
    } else {
        for &v in src {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
}

/// Inverse of [`f32s_to_le_bytes`]; `wire` must be exactly
/// `4 * dst.len()` bytes (callers validate before dispatching here).
pub fn le_bytes_to_f32s(wire: &[u8], dst: &mut [f32]) {
    debug_assert_eq!(wire.len(), 4 * dst.len());
    if cfg!(target_endian = "little") {
        let n = wire.len().min(4 * dst.len());
        unsafe {
            std::ptr::copy_nonoverlapping(wire.as_ptr(), dst.as_mut_ptr() as *mut u8, n);
        }
    } else {
        for (d, c) in dst.iter_mut().zip(wire.chunks_exact(4)) {
            *d = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
    }
}

// ---------------------------------------------------------------------------
// scalar references (public: the property suite and benches compare
// against these directly, avoiding any global dispatch mutation)
// ---------------------------------------------------------------------------

pub fn sub_scaled_diff_scalar(dst: &mut [f32], a: &[f32], b: &[f32], alpha: f32) {
    for ((t, &x), &y) in dst.iter_mut().zip(a).zip(b) {
        *t -= alpha * (x - y);
    }
}

pub fn average_scalar(dst: &mut [f32], a: &[f32], b: &[f32]) {
    for ((d, &x), &y) in dst.iter_mut().zip(a).zip(b) {
        *d = 0.5 * (x + y);
    }
}

pub fn average_in_scalar(dst: &mut [f32], y: &[f32]) {
    for (d, &v) in dst.iter_mut().zip(y) {
        *d = 0.5 * (*d + v);
    }
}

pub fn add_assign_scalar(acc: &mut [f32], x: &[f32]) {
    for (a, &v) in acc.iter_mut().zip(x) {
        *a += v;
    }
}

pub fn scale_into_scalar(dst: &mut [f32], acc: &[f32], inv: f32) {
    for (d, &a) in dst.iter_mut().zip(acc) {
        *d = a * inv;
    }
}

pub fn wacc_set_scalar(acc: &mut [f64], x: &[f32], w: f64) {
    for (a, &v) in acc.iter_mut().zip(x) {
        *a = v as f64 * w;
    }
}

pub fn wacc_add_scalar(acc: &mut [f64], x: &[f32], w: f64) {
    for (a, &v) in acc.iter_mut().zip(x) {
        *a += v as f64 * w;
    }
}

pub fn store_scaled_scalar(dst: &mut [f32], acc: &[f64], inv: f64) {
    for (d, &a) in dst.iter_mut().zip(acc) {
        *d = (a * inv) as f32;
    }
}

/// Fold the 8 lane accumulators in lane order — shared by every minmax
/// body so the combine order is part of the wire-visible contract.
fn fold8(lo: &[f32; 8], hi: &[f32; 8]) -> (f32, f32) {
    let mut flo = lo[0];
    let mut fhi = hi[0];
    for l in 1..8 {
        if lo[l] < flo {
            flo = lo[l];
        }
        if hi[l] > fhi {
            fhi = hi[l];
        }
    }
    (flo, fhi)
}

pub fn minmax_scalar(src: &[f32]) -> (f32, f32) {
    let mut lo = [f32::INFINITY; 8];
    let mut hi = [f32::NEG_INFINITY; 8];
    for (j, &v) in src.iter().enumerate() {
        let l = j & 7;
        // comparison predicates, not f32::min/max: NaN compares false
        // (skipped) and a +-0.0 tie keeps the incumbent — both exactly
        // what VMINPS(v, acc) / compare+select lanes do
        if v < lo[l] {
            lo[l] = v;
        }
        if v > hi[l] {
            hi[l] = v;
        }
    }
    fold8(&lo, &hi)
}

pub fn quant_codes_scalar(src: &[f32], lo: f32, inv: f32, max_code: i32, out: &mut [u8]) {
    for (o, &v) in out.iter_mut().zip(src) {
        // round-half-up via +0.5/truncate: deterministic, branch-free
        let q = ((v - lo) * inv + 0.5) as i32;
        *o = q.clamp(0, max_code) as u8;
    }
}

pub fn dequant_codes_scalar(codes: &[u8], lo: f32, scale: f32, dst: &mut [f32]) {
    for (d, &c) in dst.iter_mut().zip(codes) {
        *d = lo + c as f32 * scale;
    }
}

// ---------------------------------------------------------------------------
// AVX2 bodies (x86_64)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use core::arch::x86_64::*;

    #[target_feature(enable = "avx2")]
    pub unsafe fn sub_scaled_diff(dst: &mut [f32], a: &[f32], b: &[f32], alpha: f32) {
        let n = dst.len();
        let va = _mm256_set1_ps(alpha);
        let mut i = 0;
        while i + 8 <= n {
            let t = _mm256_loadu_ps(dst.as_ptr().add(i));
            let x = _mm256_loadu_ps(a.as_ptr().add(i));
            let y = _mm256_loadu_ps(b.as_ptr().add(i));
            // t - alpha*(x - y): separate mul/sub, never FMA
            let r = _mm256_sub_ps(t, _mm256_mul_ps(va, _mm256_sub_ps(x, y)));
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), r);
            i += 8;
        }
        super::sub_scaled_diff_scalar(&mut dst[i..], &a[i..n], &b[i..n], alpha);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn average(dst: &mut [f32], a: &[f32], b: &[f32]) {
        let n = dst.len();
        let half = _mm256_set1_ps(0.5);
        let mut i = 0;
        while i + 8 <= n {
            let x = _mm256_loadu_ps(a.as_ptr().add(i));
            let y = _mm256_loadu_ps(b.as_ptr().add(i));
            let r = _mm256_mul_ps(half, _mm256_add_ps(x, y));
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), r);
            i += 8;
        }
        super::average_scalar(&mut dst[i..], &a[i..n], &b[i..n]);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn average_in(dst: &mut [f32], y: &[f32]) {
        let n = dst.len();
        let half = _mm256_set1_ps(0.5);
        let mut i = 0;
        while i + 8 <= n {
            let x = _mm256_loadu_ps(dst.as_ptr().add(i));
            let v = _mm256_loadu_ps(y.as_ptr().add(i));
            let r = _mm256_mul_ps(half, _mm256_add_ps(x, v));
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), r);
            i += 8;
        }
        super::average_in_scalar(&mut dst[i..], &y[i..n]);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn add_assign(acc: &mut [f32], x: &[f32]) {
        let n = acc.len();
        let mut i = 0;
        while i + 8 <= n {
            let a = _mm256_loadu_ps(acc.as_ptr().add(i));
            let v = _mm256_loadu_ps(x.as_ptr().add(i));
            _mm256_storeu_ps(acc.as_mut_ptr().add(i), _mm256_add_ps(a, v));
            i += 8;
        }
        super::add_assign_scalar(&mut acc[i..], &x[i..n]);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn scale_into(dst: &mut [f32], acc: &[f32], inv: f32) {
        let n = dst.len();
        let vi = _mm256_set1_ps(inv);
        let mut i = 0;
        while i + 8 <= n {
            let a = _mm256_loadu_ps(acc.as_ptr().add(i));
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_mul_ps(a, vi));
            i += 8;
        }
        super::scale_into_scalar(&mut dst[i..], &acc[i..n], inv);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn wacc_set(acc: &mut [f64], x: &[f32], w: f64) {
        let n = acc.len();
        let vw = _mm256_set1_pd(w);
        let mut i = 0;
        while i + 4 <= n {
            let xf = _mm_loadu_ps(x.as_ptr().add(i));
            let xd = _mm256_cvtps_pd(xf); // f32 -> f64 is exact
            _mm256_storeu_pd(acc.as_mut_ptr().add(i), _mm256_mul_pd(xd, vw));
            i += 4;
        }
        super::wacc_set_scalar(&mut acc[i..], &x[i..n], w);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn wacc_add(acc: &mut [f64], x: &[f32], w: f64) {
        let n = acc.len();
        let vw = _mm256_set1_pd(w);
        let mut i = 0;
        while i + 4 <= n {
            let xf = _mm_loadu_ps(x.as_ptr().add(i));
            let xd = _mm256_cvtps_pd(xf);
            let a = _mm256_loadu_pd(acc.as_ptr().add(i));
            // a + x*w: separate mul/add, never FMA
            let r = _mm256_add_pd(a, _mm256_mul_pd(xd, vw));
            _mm256_storeu_pd(acc.as_mut_ptr().add(i), r);
            i += 4;
        }
        super::wacc_add_scalar(&mut acc[i..], &x[i..n], w);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn store_scaled(dst: &mut [f32], acc: &[f64], inv: f64) {
        let n = dst.len();
        let vi = _mm256_set1_pd(inv);
        let mut i = 0;
        while i + 4 <= n {
            let a = _mm256_loadu_pd(acc.as_ptr().add(i));
            // (a * inv) as f32: cvtpd_ps rounds-to-nearest like `as f32`
            let r = _mm256_cvtpd_ps(_mm256_mul_pd(a, vi));
            _mm_storeu_ps(dst.as_mut_ptr().add(i), r);
            i += 4;
        }
        super::store_scaled_scalar(&mut dst[i..], &acc[i..n], inv);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn minmax(src: &[f32]) -> (f32, f32) {
        let n = src.len();
        let mut lo = [f32::INFINITY; 8];
        let mut hi = [f32::NEG_INFINITY; 8];
        let mut vlo = _mm256_loadu_ps(lo.as_ptr());
        let mut vhi = _mm256_loadu_ps(hi.as_ptr());
        let mut i = 0;
        while i + 8 <= n {
            let v = _mm256_loadu_ps(src.as_ptr().add(i));
            // VMINPS/VMAXPS(src1=v, src2=acc) return acc on NaN and on
            // ties — exactly the scalar `if v < acc { acc = v }` predicate
            vlo = _mm256_min_ps(v, vlo);
            vhi = _mm256_max_ps(v, vhi);
            i += 8;
        }
        _mm256_storeu_ps(lo.as_mut_ptr(), vlo);
        _mm256_storeu_ps(hi.as_mut_ptr(), vhi);
        // tail: i is a multiple of 8, so element i+j folds into lane j
        for (j, &v) in src[i..].iter().enumerate() {
            if v < lo[j] {
                lo[j] = v;
            }
            if v > hi[j] {
                hi[j] = v;
            }
        }
        super::fold8(&lo, &hi)
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn quant_codes(src: &[f32], lo: f32, inv: f32, max_code: i32, out: &mut [u8]) {
        let n = src.len();
        let vlo = _mm256_set1_ps(lo);
        let vinv = _mm256_set1_ps(inv);
        let half = _mm256_set1_ps(0.5);
        let zero = _mm256_setzero_si256();
        let vmax = _mm256_set1_epi32(max_code);
        let mut tmp = [0i32; 8];
        let mut i = 0;
        while i + 8 <= n {
            let v = _mm256_loadu_ps(src.as_ptr().add(i));
            let t = _mm256_add_ps(_mm256_mul_ps(_mm256_sub_ps(v, vlo), vinv), half);
            // cvttps truncates toward zero; NaN/overflow produce
            // i32::MIN, which the max(0) below sends to 0 — matching the
            // scalar saturating `as i32` under the module's inv contract
            let mut q = _mm256_cvttps_epi32(t);
            q = _mm256_max_epi32(q, zero);
            q = _mm256_min_epi32(q, vmax);
            _mm256_storeu_si256(tmp.as_mut_ptr() as *mut __m256i, q);
            for (l, &c) in tmp.iter().enumerate() {
                *out.get_unchecked_mut(i + l) = c as u8;
            }
            i += 8;
        }
        super::quant_codes_scalar(&src[i..], lo, inv, max_code, &mut out[i..n]);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn dequant_codes(codes: &[u8], lo: f32, scale: f32, dst: &mut [f32]) {
        let n = dst.len();
        let vlo = _mm256_set1_ps(lo);
        let vs = _mm256_set1_ps(scale);
        let mut i = 0;
        while i + 8 <= n {
            let b = _mm_loadl_epi64(codes.as_ptr().add(i) as *const __m128i);
            let q = _mm256_cvtepu8_epi32(b);
            let f = _mm256_cvtepi32_ps(q); // exact for codes <= 255
            // lo + c*scale: separate mul/add, never FMA
            let r = _mm256_add_ps(vlo, _mm256_mul_ps(f, vs));
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), r);
            i += 8;
        }
        super::dequant_codes_scalar(&codes[i..n], lo, scale, &mut dst[i..]);
    }
}

// ---------------------------------------------------------------------------
// NEON bodies (aarch64)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use core::arch::aarch64::*;

    #[target_feature(enable = "neon")]
    pub unsafe fn sub_scaled_diff(dst: &mut [f32], a: &[f32], b: &[f32], alpha: f32) {
        let n = dst.len();
        let va = vdupq_n_f32(alpha);
        let mut i = 0;
        while i + 4 <= n {
            let t = vld1q_f32(dst.as_ptr().add(i));
            let x = vld1q_f32(a.as_ptr().add(i));
            let y = vld1q_f32(b.as_ptr().add(i));
            // t - alpha*(x - y): vmulq + vsubq, never vfmaq
            let r = vsubq_f32(t, vmulq_f32(va, vsubq_f32(x, y)));
            vst1q_f32(dst.as_mut_ptr().add(i), r);
            i += 4;
        }
        super::sub_scaled_diff_scalar(&mut dst[i..], &a[i..n], &b[i..n], alpha);
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn average(dst: &mut [f32], a: &[f32], b: &[f32]) {
        let n = dst.len();
        let half = vdupq_n_f32(0.5);
        let mut i = 0;
        while i + 4 <= n {
            let x = vld1q_f32(a.as_ptr().add(i));
            let y = vld1q_f32(b.as_ptr().add(i));
            vst1q_f32(dst.as_mut_ptr().add(i), vmulq_f32(half, vaddq_f32(x, y)));
            i += 4;
        }
        super::average_scalar(&mut dst[i..], &a[i..n], &b[i..n]);
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn average_in(dst: &mut [f32], y: &[f32]) {
        let n = dst.len();
        let half = vdupq_n_f32(0.5);
        let mut i = 0;
        while i + 4 <= n {
            let x = vld1q_f32(dst.as_ptr().add(i));
            let v = vld1q_f32(y.as_ptr().add(i));
            vst1q_f32(dst.as_mut_ptr().add(i), vmulq_f32(half, vaddq_f32(x, v)));
            i += 4;
        }
        super::average_in_scalar(&mut dst[i..], &y[i..n]);
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn add_assign(acc: &mut [f32], x: &[f32]) {
        let n = acc.len();
        let mut i = 0;
        while i + 4 <= n {
            let a = vld1q_f32(acc.as_ptr().add(i));
            let v = vld1q_f32(x.as_ptr().add(i));
            vst1q_f32(acc.as_mut_ptr().add(i), vaddq_f32(a, v));
            i += 4;
        }
        super::add_assign_scalar(&mut acc[i..], &x[i..n]);
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn scale_into(dst: &mut [f32], acc: &[f32], inv: f32) {
        let n = dst.len();
        let vi = vdupq_n_f32(inv);
        let mut i = 0;
        while i + 4 <= n {
            let a = vld1q_f32(acc.as_ptr().add(i));
            vst1q_f32(dst.as_mut_ptr().add(i), vmulq_f32(a, vi));
            i += 4;
        }
        super::scale_into_scalar(&mut dst[i..], &acc[i..n], inv);
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn wacc_set(acc: &mut [f64], x: &[f32], w: f64) {
        let n = acc.len();
        let vw = vdupq_n_f64(w);
        let mut i = 0;
        while i + 2 <= n {
            let xf = vld1_f32(x.as_ptr().add(i));
            let xd = vcvt_f64_f32(xf); // exact widening
            vst1q_f64(acc.as_mut_ptr().add(i), vmulq_f64(xd, vw));
            i += 2;
        }
        super::wacc_set_scalar(&mut acc[i..], &x[i..n], w);
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn wacc_add(acc: &mut [f64], x: &[f32], w: f64) {
        let n = acc.len();
        let vw = vdupq_n_f64(w);
        let mut i = 0;
        while i + 2 <= n {
            let xf = vld1_f32(x.as_ptr().add(i));
            let xd = vcvt_f64_f32(xf);
            let a = vld1q_f64(acc.as_ptr().add(i));
            // a + x*w: vmulq + vaddq, never vfmaq
            vst1q_f64(acc.as_mut_ptr().add(i), vaddq_f64(a, vmulq_f64(xd, vw)));
            i += 2;
        }
        super::wacc_add_scalar(&mut acc[i..], &x[i..n], w);
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn store_scaled(dst: &mut [f32], acc: &[f64], inv: f64) {
        let n = dst.len();
        let vi = vdupq_n_f64(inv);
        let mut i = 0;
        while i + 2 <= n {
            let a = vld1q_f64(acc.as_ptr().add(i));
            // (a * inv) as f32: fcvtn rounds-to-nearest like `as f32`
            let r = vcvt_f32_f64(vmulq_f64(a, vi));
            vst1_f32(dst.as_mut_ptr().add(i), r);
            i += 2;
        }
        super::store_scaled_scalar(&mut dst[i..], &acc[i..n], inv);
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn minmax(src: &[f32]) -> (f32, f32) {
        let n = src.len();
        let mut lo = [f32::INFINITY; 8];
        let mut hi = [f32::NEG_INFINITY; 8];
        // lanes 0..3 and 4..7 as a register pair — the same 8-lane
        // virtual stride as the scalar reference
        let mut lo0 = vld1q_f32(lo.as_ptr());
        let mut lo1 = vld1q_f32(lo.as_ptr().add(4));
        let mut hi0 = vld1q_f32(hi.as_ptr());
        let mut hi1 = vld1q_f32(hi.as_ptr().add(4));
        let mut i = 0;
        while i + 8 <= n {
            let v0 = vld1q_f32(src.as_ptr().add(i));
            let v1 = vld1q_f32(src.as_ptr().add(i + 4));
            // compare+select, not vminq: NaN compares false (skipped)
            // and +-0.0 ties keep the incumbent
            lo0 = vbslq_f32(vcltq_f32(v0, lo0), v0, lo0);
            lo1 = vbslq_f32(vcltq_f32(v1, lo1), v1, lo1);
            hi0 = vbslq_f32(vcgtq_f32(v0, hi0), v0, hi0);
            hi1 = vbslq_f32(vcgtq_f32(v1, hi1), v1, hi1);
            i += 8;
        }
        vst1q_f32(lo.as_mut_ptr(), lo0);
        vst1q_f32(lo.as_mut_ptr().add(4), lo1);
        vst1q_f32(hi.as_mut_ptr(), hi0);
        vst1q_f32(hi.as_mut_ptr().add(4), hi1);
        for (j, &v) in src[i..].iter().enumerate() {
            if v < lo[j] {
                lo[j] = v;
            }
            if v > hi[j] {
                hi[j] = v;
            }
        }
        super::fold8(&lo, &hi)
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn quant_codes(src: &[f32], lo: f32, inv: f32, max_code: i32, out: &mut [u8]) {
        let n = src.len();
        let vlo = vdupq_n_f32(lo);
        let vinv = vdupq_n_f32(inv);
        let half = vdupq_n_f32(0.5);
        let zero = vdupq_n_s32(0);
        let vmax = vdupq_n_s32(max_code);
        let mut tmp = [0i32; 4];
        let mut i = 0;
        while i + 4 <= n {
            let v = vld1q_f32(src.as_ptr().add(i));
            let t = vaddq_f32(vmulq_f32(vsubq_f32(v, vlo), vinv), half);
            // fcvtzs: truncate toward zero, NaN -> 0, saturating — the
            // exact semantics of Rust's `as i32`
            let mut q = vcvtq_s32_f32(t);
            q = vmaxq_s32(q, zero);
            q = vminq_s32(q, vmax);
            vst1q_s32(tmp.as_mut_ptr(), q);
            for (l, &c) in tmp.iter().enumerate() {
                *out.get_unchecked_mut(i + l) = c as u8;
            }
            i += 4;
        }
        super::quant_codes_scalar(&src[i..], lo, inv, max_code, &mut out[i..n]);
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn dequant_codes(codes: &[u8], lo: f32, scale: f32, dst: &mut [f32]) {
        let n = dst.len();
        let vlo = vdupq_n_f32(lo);
        let vs = vdupq_n_f32(scale);
        let mut i = 0;
        while i + 8 <= n {
            let b = vld1_u8(codes.as_ptr().add(i));
            let w = vmovl_u8(b); // u8 -> u16
            let q0 = vmovl_u16(vget_low_u16(w)); // -> u32
            let q1 = vmovl_u16(vget_high_u16(w));
            let f0 = vcvtq_f32_u32(q0); // exact for codes <= 255
            let f1 = vcvtq_f32_u32(q1);
            let r0 = vaddq_f32(vlo, vmulq_f32(f0, vs));
            let r1 = vaddq_f32(vlo, vmulq_f32(f1, vs));
            vst1q_f32(dst.as_mut_ptr().add(i), r0);
            vst1q_f32(dst.as_mut_ptr().add(i + 4), r1);
            i += 8;
        }
        super::dequant_codes_scalar(&codes[i..n], lo, scale, &mut dst[i..]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Awkward lengths: empty, sub-lane, lane boundaries for both 4- and
    /// 8-wide registers, and primes that leave ragged tails.
    const LENS: &[usize] = &[0, 1, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 97, 1009];

    fn awkward_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut v: Vec<f32> = (0..n).map(|_| rng.gauss_f32() * 3.0).collect();
        // salt with the values folds must handle deterministically
        for (k, x) in v.iter_mut().enumerate() {
            match k % 11 {
                3 => *x = 0.0,
                7 => *x = -0.0,
                9 => *x = f32::MIN_POSITIVE / 2.0, // subnormal
                _ => {}
            }
        }
        v
    }

    #[test]
    fn dispatch_level_is_cached_and_named() {
        let l = level();
        assert_eq!(l, level(), "level must be stable across calls");
        let name = active_name();
        assert!(["scalar", "avx2", "neon"].contains(&name), "{name}");
    }

    #[test]
    fn elementwise_kernels_match_scalar_bitwise() {
        for &n in LENS {
            let a = awkward_vec(n, 1);
            let b = awkward_vec(n, 2);
            let base = awkward_vec(n, 3);

            let mut d1 = base.clone();
            let mut d2 = base.clone();
            sub_scaled_diff(&mut d1, &a, &b, 0.3);
            sub_scaled_diff_scalar(&mut d2, &a, &b, 0.3);
            assert_eq!(bits(&d1), bits(&d2), "sub_scaled_diff n={n}");

            let mut d1 = base.clone();
            let mut d2 = base.clone();
            average(&mut d1, &a, &b);
            average_scalar(&mut d2, &a, &b);
            assert_eq!(bits(&d1), bits(&d2), "average n={n}");

            let mut d1 = base.clone();
            let mut d2 = base.clone();
            average_in(&mut d1, &a);
            average_in_scalar(&mut d2, &a);
            assert_eq!(bits(&d1), bits(&d2), "average_in n={n}");

            let mut d1 = base.clone();
            let mut d2 = base.clone();
            add_assign(&mut d1, &a);
            add_assign_scalar(&mut d2, &a);
            assert_eq!(bits(&d1), bits(&d2), "add_assign n={n}");

            let mut d1 = vec![0.0; n];
            let mut d2 = vec![0.0; n];
            scale_into(&mut d1, &base, 0.125);
            scale_into_scalar(&mut d2, &base, 0.125);
            assert_eq!(bits(&d1), bits(&d2), "scale_into n={n}");
        }
    }

    #[test]
    fn f64_accumulator_kernels_match_scalar_bitwise() {
        for &n in LENS {
            let x = awkward_vec(n, 5);
            let mut a1 = vec![0.0f64; n];
            let mut a2 = vec![0.0f64; n];
            wacc_set(&mut a1, &x, 0.6);
            wacc_set_scalar(&mut a2, &x, 0.6);
            assert_eq!(bits64(&a1), bits64(&a2), "wacc_set n={n}");
            wacc_add(&mut a1, &x, 0.35);
            wacc_add_scalar(&mut a2, &x, 0.35);
            assert_eq!(bits64(&a1), bits64(&a2), "wacc_add n={n}");
            let mut d1 = vec![0.0f32; n];
            let mut d2 = vec![0.0f32; n];
            store_scaled(&mut d1, &a1, 1.0 / 0.95);
            store_scaled_scalar(&mut d2, &a2, 1.0 / 0.95);
            assert_eq!(bits(&d1), bits(&d2), "store_scaled n={n}");
        }
    }

    #[test]
    fn minmax_matches_scalar_bitwise_with_nans() {
        for &n in LENS {
            let mut v = awkward_vec(n, 9);
            if n > 2 {
                v[n / 2] = f32::NAN; // folds must skip it identically
            }
            let (l1, h1) = minmax(&v);
            let (l2, h2) = minmax_scalar(&v);
            assert_eq!(l1.to_bits(), l2.to_bits(), "min n={n}");
            assert_eq!(h1.to_bits(), h2.to_bits(), "max n={n}");
        }
        // empty input is the fold identity
        assert_eq!(minmax_scalar(&[]), (f32::INFINITY, f32::NEG_INFINITY));
    }

    #[test]
    fn quant_dequant_match_scalar_bitwise() {
        for &n in LENS {
            let v = awkward_vec(n, 13);
            let (lo, hi) = minmax_scalar(&v);
            let range = hi - lo;
            for max_code in [255i32, 15] {
                let inv =
                    if range > f32::MIN_POSITIVE { max_code as f32 / range } else { 0.0 };
                let mut c1 = vec![0u8; n];
                let mut c2 = vec![0u8; n];
                quant_codes(&v, lo, inv, max_code, &mut c1);
                quant_codes_scalar(&v, lo, inv, max_code, &mut c2);
                assert_eq!(c1, c2, "quant_codes n={n} max={max_code}");
                let scale = if inv > 0.0 { range / max_code as f32 } else { 0.0 };
                let mut d1 = vec![0.0f32; n];
                let mut d2 = vec![0.0f32; n];
                dequant_codes(&c1, lo, scale, &mut d1);
                dequant_codes_scalar(&c2, lo, scale, &mut d2);
                assert_eq!(bits(&d1), bits(&d2), "dequant_codes n={n} max={max_code}");
            }
        }
    }

    #[test]
    fn le_byte_paths_roundtrip_bit_exact() {
        let mut v = awkward_vec(333, 17);
        v[0] = f32::NAN;
        v[1] = f32::NEG_INFINITY;
        let mut wire = Vec::new();
        f32s_to_le_bytes(&v, &mut wire);
        assert_eq!(wire.len(), 4 * v.len());
        // matches the per-element reference encoding
        for (c, &x) in wire.chunks_exact(4).zip(&v) {
            assert_eq!(c, &x.to_le_bytes());
        }
        let mut back = vec![0.0f32; v.len()];
        le_bytes_to_f32s(&wire, &mut back);
        assert_eq!(bits(&v), bits(&back));
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    fn bits64(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }
}
