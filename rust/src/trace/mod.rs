//! Deterministic flight-recorder telemetry: spans, instants, counters.
//!
//! Every layer of the stack (gradient steps, codec encode/decode,
//! snapshot copies, shard-heap pops, transport send/recv, membership
//! events) can emit structured records into a bounded ring buffer — the
//! **flight recorder** — which dumps the last N events as Chrome
//! trace-event JSON (loadable in `chrome://tracing` and Perfetto) on
//! panic, on golden-digest mismatch, or on demand (`repro trace-dump`).
//!
//! Two hard invariants, both property-tested:
//!
//! * **Zero overhead when off.**  [`Trace`] is an `Option<Box<Tracer>>`;
//!   the default (`trace = "off"`) is `None`, every emission is a branch
//!   on it, and no buffer is ever allocated.  Trajectories, ledgers and
//!   the allocation fingerprint are bit-identical to a build without the
//!   plane.
//! * **Deterministic when on.**  In the simulators every record is keyed
//!   by the *virtual* clock, and its identity derives from
//!   `(virtual_time, class, seq)` — the same total order the event queue
//!   itself uses — never from wall time or allocation order.  Two
//!   same-seed runs emit byte-identical trace files.  The opt-in `wall`
//!   clause attaches host wall-clock micros as an extra arg and is the
//!   one documented exception; `net-train` timelines are wall-clock by
//!   nature ([`Trace::span_us`]).
//!
//! The module also owns the unified counter/gauge [`Registry`] that
//! backs the communication fabric's [`TrafficReport`]
//! (`comm::TrafficReport` is assembled from it as a view, so the public
//! report fields — and the golden fixtures pinned on them — are
//! unchanged).

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::manifest::json::{self, Json, JsonObj};

// ---------------------------------------------------------------------------
// spec grammar
// ---------------------------------------------------------------------------

/// Parsed `trace:` spec (`trace` config key / `--trace` CLI flag).
///
/// Grammar (comma-separated clauses, first must be `on` or `off`):
///
/// ```text
/// off                         # default: plane absent, zero overhead
/// on                          # ring of 4096 records, virtual clock
/// on,ring:65536               # bigger flight recorder
/// on,wall                     # attach wall-clock micros (non-deterministic)
/// on,dump:flight.json         # always dump here at end of run
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct TraceSpec {
    pub on: bool,
    /// flight-recorder capacity in records (default 4096)
    pub ring: usize,
    /// attach host wall-clock micros to every record as an extra arg —
    /// explicitly non-deterministic, excluded from byte-identity tests
    pub wall: bool,
    /// write the trace here when the run finishes (panic dumps and
    /// `repro trace-dump` fall back to `trace-<label>.json`)
    pub dump: Option<PathBuf>,
}

impl Default for TraceSpec {
    fn default() -> Self {
        TraceSpec::off()
    }
}

pub const DEFAULT_RING: usize = 4096;

impl TraceSpec {
    pub fn off() -> Self {
        TraceSpec { on: false, ring: DEFAULT_RING, wall: false, dump: None }
    }

    pub fn on() -> Self {
        TraceSpec { on: true, ..TraceSpec::off() }
    }

    pub fn is_off(&self) -> bool {
        !self.on
    }

    /// Parse the `trace:` grammar (see the type docs).
    pub fn parse(s: &str) -> Result<TraceSpec> {
        let mut parts = s.split(',');
        let head = parts.next().unwrap_or("").trim();
        let mut spec = match head {
            "off" => TraceSpec::off(),
            "on" => TraceSpec::on(),
            other => bail!(
                "trace spec must start with `on` or `off`, got {other:?} \
                 (grammar: off | on[,ring:<n>][,wall][,dump:<path>])"
            ),
        };
        for clause in parts {
            let clause = clause.trim();
            if spec.is_off() {
                bail!("trace clause {clause:?} after `off` has no effect; drop it");
            }
            if let Some(n) = clause.strip_prefix("ring:") {
                let n: usize = n
                    .parse()
                    .with_context(|| format!("bad trace ring capacity {n:?}"))?;
                if n == 0 {
                    bail!("trace ring capacity must be >= 1");
                }
                spec.ring = n;
            } else if clause == "wall" {
                spec.wall = true;
            } else if let Some(p) = clause.strip_prefix("dump:") {
                if p.is_empty() {
                    bail!("trace dump path is empty");
                }
                spec.dump = Some(PathBuf::from(p));
            } else {
                bail!(
                    "unknown trace clause {clause:?} \
                     (grammar: off | on[,ring:<n>][,wall][,dump:<path>])"
                );
            }
        }
        Ok(spec)
    }

    /// Canonical round-trippable form of the spec.
    pub fn label(&self) -> String {
        if self.is_off() {
            return "off".into();
        }
        let mut out = String::from("on");
        if self.ring != DEFAULT_RING {
            let _ = write!(out, ",ring:{}", self.ring);
        }
        if self.wall {
            out.push_str(",wall");
        }
        if let Some(p) = &self.dump {
            let _ = write!(out, ",dump:{}", p.display());
        }
        out
    }
}

// ---------------------------------------------------------------------------
// unified counter / gauge registry
// ---------------------------------------------------------------------------

/// Monotonic `u64` counters — the scalar ledgers that were previously
/// ad-hoc fields scattered across `TrafficReport`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum Ctr {
    /// raw (logical) payload bytes put on the fabric
    CommBytes = 0,
    /// encoded bytes actually on the wire
    WireBytes,
    /// logical messages sent
    Messages,
    /// physical wire frames (== messages unless coalescing packed several)
    Frames,
    /// synchronous barrier rounds closed
    Rounds,
    /// membership-rule drops (receiver departed / sender refused)
    DroppedMessages,
    /// raw bytes of the membership-rule drops
    DroppedBytes,
    /// network losses from the fault plane (link drop / partition)
    LinkLostMessages,
    /// raw bytes of the network losses
    LinkLostBytes,
    /// inbound wire frames that failed decoding
    MalformedFrames,
}

pub const CTR_COUNT: usize = 10;

pub const CTR_NAMES: [&str; CTR_COUNT] = [
    "comm_bytes",
    "wire_bytes",
    "messages",
    "frames",
    "rounds",
    "dropped_messages",
    "dropped_bytes",
    "link_lost_messages",
    "link_lost_bytes",
    "malformed_frames",
];

/// Floating-point gauges.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum Gauge {
    /// simulated seconds spent on communication
    SimulatedCommS = 0,
}

pub const GAUGE_COUNT: usize = 1;

pub const GAUGE_NAMES: [&str; GAUGE_COUNT] = ["simulated_comm_s"];

/// Fixed-slot counter/gauge store: an enum-indexed array, no maps, no
/// allocation after construction, `PartialEq` so replay determinism can
/// be asserted on whole registries.
#[derive(Clone, Debug, PartialEq)]
pub struct Registry {
    ctrs: [u64; CTR_COUNT],
    gauges: [f64; GAUGE_COUNT],
}

impl Default for Registry {
    fn default() -> Self {
        Registry { ctrs: [0; CTR_COUNT], gauges: [0.0; GAUGE_COUNT] }
    }
}

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    #[inline]
    pub fn add(&mut self, c: Ctr, v: u64) {
        self.ctrs[c as usize] += v;
    }

    #[inline]
    pub fn inc(&mut self, c: Ctr) {
        self.add(c, 1);
    }

    #[inline]
    pub fn get(&self, c: Ctr) -> u64 {
        self.ctrs[c as usize]
    }

    #[inline]
    pub fn gauge_add(&mut self, g: Gauge, v: f64) {
        self.gauges[g as usize] += v;
    }

    #[inline]
    pub fn gauge(&self, g: Gauge) -> f64 {
        self.gauges[g as usize]
    }

    pub fn reset(&mut self) {
        *self = Registry::default();
    }

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        for (i, name) in CTR_NAMES.iter().enumerate() {
            o.insert(*name, Json::Num(self.ctrs[i] as f64));
        }
        for (i, name) in GAUGE_NAMES.iter().enumerate() {
            o.insert(*name, Json::Num(self.gauges[i]));
        }
        Json::Obj(o)
    }
}

// ---------------------------------------------------------------------------
// trace records
// ---------------------------------------------------------------------------

/// What a record describes.  The name doubles as the Chrome event `name`
/// and `cat`, so kinds are filterable in Perfetto.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Kind {
    /// gradient compute span (`a` = step index)
    Step = 0,
    /// message flight span send -> deliver (`a` = dst, `b` = wire bytes)
    Flight = 1,
    /// codec encode instant (`a` = raw bytes, `b` = encoded bytes)
    Encode = 2,
    /// codec decode instant (`a` = wire bytes, `b` = decoded f32 count)
    Decode = 3,
    /// arena snapshot copy instant (`a` = messages applied)
    Snapshot = 4,
    /// shard-heap pop instant (`a` = event class, `b` = shard)
    Pop = 5,
    /// evaluation instant (`a` = eval index)
    Eval = 6,
    /// synchronous comm round span (`a` = communicating workers)
    Round = 7,
    /// membership change instant (`a` = 0 depart / 1 arrive)
    Churn = 8,
    /// failure-detector instant (`a` = 0 suspect / 1 confirm / 2 refute)
    Fd = 9,
    /// transport send instant (`a` = dst, `b` = wire bytes)
    Send = 10,
    /// transport receive instant (`a` = src, `b` = wire bytes)
    Recv = 11,
    /// free-form marker
    Mark = 12,
}

pub const KIND_NAMES: [&str; 13] = [
    "step", "flight", "encode", "decode", "snapshot", "pop", "eval", "round", "churn", "fd",
    "send", "recv", "mark",
];

impl Kind {
    pub fn name(self) -> &'static str {
        KIND_NAMES[self as usize]
    }
}

/// Emission-site descriptor: who/what, plus the `(class, seq)` half of
/// the record identity (the time half comes from the emission call).
#[derive(Clone, Copy, Debug)]
pub struct Ev {
    pub node: usize,
    pub kind: Kind,
    /// event class from the runtime's `(time, class, seq)` total order
    /// (0 in contexts without one, e.g. the synchronous coordinator)
    pub class: u8,
    /// scheduling sequence number — the deterministic tie-breaker
    pub seq: u64,
    pub a: u64,
    pub b: u64,
}

/// One fixed-size flight-recorder record.  `Copy` and field-only — the
/// ring never allocates per event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Rec {
    pub ts_us: u64,
    pub dur_us: u64,
    pub node: u32,
    pub kind: Kind,
    pub class: u8,
    pub seq: u64,
    pub a: u64,
    pub b: u64,
    /// populated only in `wall` mode (and excluded from determinism)
    pub wall_us: u64,
}

const REC_ZERO: Rec =
    Rec { ts_us: 0, dur_us: 0, node: 0, kind: Kind::Mark, class: 0, seq: 0, a: 0, b: 0, wall_us: 0 };

/// Virtual seconds -> integer microseconds.  Rounding is a pure function
/// of the f64 bit pattern, so the mapping is deterministic.
#[inline]
fn us(t_s: f64) -> u64 {
    let v = (t_s * 1e6).round();
    if v <= 0.0 {
        0
    } else {
        v as u64
    }
}

// ---------------------------------------------------------------------------
// the tracer + its zero-overhead facade
// ---------------------------------------------------------------------------

/// The live flight recorder: a preallocated ring of [`Rec`]s.
pub struct Tracer {
    label: String,
    ring: Box<[Rec]>,
    /// next slot to write
    head: usize,
    /// live records (saturates at capacity)
    len: usize,
    /// records ever emitted (ring may have evicted older ones)
    total: u64,
    wall: bool,
    dump: Option<PathBuf>,
    t0: std::time::Instant,
    dumped: bool,
}

impl Tracer {
    fn new(spec: &TraceSpec, label: &str) -> Tracer {
        Tracer {
            label: label.to_string(),
            ring: vec![REC_ZERO; spec.ring].into_boxed_slice(),
            head: 0,
            len: 0,
            total: 0,
            wall: spec.wall,
            dump: spec.dump.clone(),
            t0: std::time::Instant::now(),
            dumped: false,
        }
    }

    #[inline]
    fn record(&mut self, mut r: Rec) {
        if self.wall {
            r.wall_us = self.t0.elapsed().as_micros() as u64;
        }
        self.ring[self.head] = r;
        self.head = (self.head + 1) % self.ring.len();
        self.len = (self.len + 1).min(self.ring.len());
        self.total += 1;
    }

    /// Ring contents oldest-first.
    fn iter(&self) -> impl Iterator<Item = &Rec> {
        let cap = self.ring.len();
        let start = if self.len < cap { 0 } else { self.head };
        (0..self.len).map(move |i| &self.ring[(start + i) % cap])
    }

    /// Serialize the ring as Chrome trace-event JSON (the "JSON object
    /// format": `{"traceEvents": [...]}`), oldest record first.  All
    /// numeric fields are integers, so the byte output is a pure
    /// function of the recorded events.
    pub fn to_chrome_json(&self) -> String {
        let mut s = String::with_capacity(self.len * 112 + 256);
        s.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[\n");
        let _ = write!(
            s,
            "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":0,\"tid\":0,\
             \"args\":{{\"name\":{}}}}}",
            json::write(&Json::Str(self.label.clone()))
        );
        for r in self.iter() {
            s.push_str(",\n");
            let name = r.kind.name();
            if r.dur_us > 0 {
                let _ = write!(
                    s,
                    "{{\"name\":\"{name}\",\"cat\":\"{name}\",\"ph\":\"X\",\
                     \"ts\":{},\"dur\":{},\"pid\":0,\"tid\":{}",
                    r.ts_us, r.dur_us, r.node
                );
            } else {
                let _ = write!(
                    s,
                    "{{\"name\":\"{name}\",\"cat\":\"{name}\",\"ph\":\"i\",\"s\":\"t\",\
                     \"ts\":{},\"pid\":0,\"tid\":{}",
                    r.ts_us, r.node
                );
            }
            let _ = write!(s, ",\"args\":{{\"class\":{},\"seq\":{},\"a\":{},\"b\":{}", r.class, r.seq, r.a, r.b);
            if self.wall {
                let _ = write!(s, ",\"wall_us\":{}", r.wall_us);
            }
            s.push_str("}}");
        }
        s.push_str("\n]}\n");
        s
    }

    fn default_dump_path(&self) -> PathBuf {
        let safe: String = self
            .label
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-') { c } else { '-' })
            .collect();
        PathBuf::from(format!("trace-{safe}.json"))
    }

    /// Write the flight recorder to `path` (or the spec's `dump:` path,
    /// or `trace-<label>.json`).  Returns the path written.
    pub fn write_dump(&mut self, path: Option<&Path>) -> Result<PathBuf> {
        let target: PathBuf = path
            .map(Path::to_path_buf)
            .or_else(|| self.dump.clone())
            .unwrap_or_else(|| self.default_dump_path());
        std::fs::write(&target, self.to_chrome_json())
            .with_context(|| format!("writing trace dump {}", target.display()))?;
        self.dumped = true;
        Ok(target)
    }
}

impl Drop for Tracer {
    /// Panic dump: if the thread is unwinding and the ring was never
    /// dumped, write it best-effort so the last N events survive the
    /// crash (the flight-recorder contract).
    fn drop(&mut self) {
        if std::thread::panicking() && !self.dumped && self.total > 0 {
            let path =
                self.dump.clone().unwrap_or_else(|| self.default_dump_path());
            if std::fs::write(&path, self.to_chrome_json()).is_ok() {
                eprintln!(
                    "trace: flight recorder dumped {} of {} events to {}",
                    self.len,
                    self.total,
                    path.display()
                );
            }
        }
    }
}

/// The facade every layer holds.  `off` is `None`: no buffer, no clock,
/// no branch beyond the `Option` check — the zero-overhead contract.
pub struct Trace {
    t: Option<Box<Tracer>>,
}

impl Trace {
    pub fn off() -> Trace {
        Trace { t: None }
    }

    pub fn from_spec(spec: &TraceSpec, label: &str) -> Trace {
        if spec.is_off() {
            Trace::off()
        } else {
            Trace { t: Some(Box::new(Tracer::new(spec, label))) }
        }
    }

    #[inline]
    pub fn is_on(&self) -> bool {
        self.t.is_some()
    }

    /// A span on the virtual clock: `[t0_s, t1_s]` in virtual seconds.
    /// Zero-length spans degrade to instants so Perfetto renders them.
    #[inline]
    pub fn span(&mut self, t0_s: f64, t1_s: f64, ev: Ev) {
        if let Some(t) = self.t.as_deref_mut() {
            t.record(Rec {
                ts_us: us(t0_s),
                dur_us: us(t1_s).saturating_sub(us(t0_s)),
                node: ev.node as u32,
                kind: ev.kind,
                class: ev.class,
                seq: ev.seq,
                a: ev.a,
                b: ev.b,
                wall_us: 0,
            });
        }
    }

    /// An instant on the virtual clock.
    #[inline]
    pub fn instant(&mut self, t_s: f64, ev: Ev) {
        self.span(t_s, t_s, ev);
    }

    /// A span in raw microseconds — the wall-clock timeline used by
    /// `net-train`, where there is no virtual clock.
    #[inline]
    pub fn span_us(&mut self, ts_us: u64, dur_us: u64, ev: Ev) {
        if let Some(t) = self.t.as_deref_mut() {
            t.record(Rec {
                ts_us,
                dur_us,
                node: ev.node as u32,
                kind: ev.kind,
                class: ev.class,
                seq: ev.seq,
                a: ev.a,
                b: ev.b,
                wall_us: 0,
            });
        }
    }

    /// An instant in raw microseconds (wall-clock timelines).
    #[inline]
    pub fn instant_us(&mut self, ts_us: u64, ev: Ev) {
        self.span_us(ts_us, 0, ev);
    }

    /// Microseconds since the tracer was created (0 when off) — the
    /// wall-clock timebase for `net-train` records.
    #[inline]
    pub fn elapsed_us(&self) -> u64 {
        match &self.t {
            Some(t) => t.t0.elapsed().as_micros() as u64,
            None => 0,
        }
    }

    /// Records ever emitted (the ring may hold fewer).
    pub fn events_recorded(&self) -> u64 {
        self.t.as_ref().map_or(0, |t| t.total)
    }

    /// Records currently held by the ring.
    pub fn events_held(&self) -> usize {
        self.t.as_ref().map_or(0, |t| t.len)
    }

    /// Chrome trace-event JSON of the ring; `None` when off.
    pub fn to_chrome_json(&self) -> Option<String> {
        self.t.as_ref().map(|t| t.to_chrome_json())
    }

    /// On-demand dump (also the end-of-run dump when the spec carries a
    /// `dump:` path).  `Ok(None)` when the plane is off.
    pub fn dump(&mut self, path: Option<&Path>) -> Result<Option<PathBuf>> {
        match self.t.as_deref_mut() {
            Some(t) => t.write_dump(path).map(Some),
            None => Ok(None),
        }
    }

    /// Dump only if the spec asked for one (`dump:` clause).
    pub fn dump_if_requested(&mut self) -> Result<Option<PathBuf>> {
        match self.t.as_deref_mut() {
            Some(t) if t.dump.is_some() => t.write_dump(None).map(Some),
            _ => Ok(None),
        }
    }
}

// ---------------------------------------------------------------------------
// Chrome trace-event validation (used by `just trace-smoke` and tests)
// ---------------------------------------------------------------------------

/// Validate `text` against the Chrome trace-event JSON object format:
/// a top-level `traceEvents` array whose entries carry `name`/`ph`/
/// `pid`/`tid`, with `ts` (+ `dur` for complete events) on every
/// non-metadata event.  Returns the number of non-metadata events.
pub fn validate_chrome_trace(text: &str) -> Result<usize> {
    let j = json::parse(text).map_err(|e| anyhow!("trace is not valid JSON: {e}"))?;
    let events = j
        .path(&["traceEvents"])
        .as_arr()
        .ok_or_else(|| anyhow!("trace has no traceEvents array"))?;
    let mut n = 0usize;
    for (i, e) in events.iter().enumerate() {
        let obj = e.as_obj().ok_or_else(|| anyhow!("traceEvents[{i}] is not an object"))?;
        let ph = obj
            .get("ph")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("traceEvents[{i}] has no ph"))?;
        for key in ["name", "pid"] {
            if obj.get(key).is_none() {
                bail!("traceEvents[{i}] ({ph}) is missing {key:?}");
            }
        }
        match ph {
            "M" => continue, // metadata: no timestamp required
            "X" => {
                for key in ["ts", "dur", "tid"] {
                    if obj.get(key).and_then(Json::as_f64).is_none() {
                        bail!("complete event traceEvents[{i}] is missing numeric {key:?}");
                    }
                }
            }
            "i" | "I" => {
                for key in ["ts", "tid"] {
                    if obj.get(key).and_then(Json::as_f64).is_none() {
                        bail!("instant event traceEvents[{i}] is missing numeric {key:?}");
                    }
                }
            }
            "C" | "B" | "E" => {
                if obj.get("ts").and_then(Json::as_f64).is_none() {
                    bail!("event traceEvents[{i}] ({ph}) is missing numeric ts");
                }
            }
            other => bail!("traceEvents[{i}] has unknown phase {other:?}"),
        }
        n += 1;
    }
    Ok(n)
}

// ---------------------------------------------------------------------------
// percentile helper (shared by the bucketed histograms)
// ---------------------------------------------------------------------------

/// Smallest bucket index whose cumulative count reaches `p` (in `[0,1]`)
/// of the total — the standard bucketed-histogram percentile.  `None`
/// when the histogram is empty.
pub fn percentile_bucket(counts: &[u64], p: f64) -> Option<usize> {
    let n: u64 = counts.iter().sum();
    if n == 0 {
        return None;
    }
    let target = ((p * n as f64).ceil() as u64).clamp(1, n);
    let mut cum = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        cum += c;
        if cum >= target {
            return Some(i);
        }
    }
    Some(counts.len() - 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_grammar_roundtrip() {
        assert_eq!(TraceSpec::parse("off").unwrap(), TraceSpec::off());
        assert_eq!(TraceSpec::parse("on").unwrap(), TraceSpec::on());
        let s = TraceSpec::parse("on,ring:16,wall,dump:x.json").unwrap();
        assert!(s.on && s.wall);
        assert_eq!(s.ring, 16);
        assert_eq!(s.dump.as_deref(), Some(Path::new("x.json")));
        assert_eq!(TraceSpec::parse(&s.label()).unwrap(), s);
        assert_eq!(TraceSpec::off().label(), "off");
        assert_eq!(TraceSpec::on().label(), "on");
        for bad in ["", "maybe", "on,ring:0", "on,ring:x", "off,wall", "on,beep", "on,dump:"] {
            assert!(TraceSpec::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn registry_counts_and_resets() {
        let mut r = Registry::new();
        r.add(Ctr::CommBytes, 100);
        r.inc(Ctr::Messages);
        r.inc(Ctr::Messages);
        r.gauge_add(Gauge::SimulatedCommS, 0.5);
        assert_eq!(r.get(Ctr::CommBytes), 100);
        assert_eq!(r.get(Ctr::Messages), 2);
        assert_eq!(r.get(Ctr::WireBytes), 0);
        assert_eq!(r.gauge(Gauge::SimulatedCommS), 0.5);
        let j = json::write(&r.to_json());
        assert!(j.contains("\"messages\":2"), "{j}");
        r.reset();
        assert_eq!(r, Registry::new());
    }

    #[test]
    fn off_trace_records_nothing_and_emits_nothing() {
        let mut t = Trace::off();
        assert!(!t.is_on());
        t.span(0.0, 1.0, Ev { node: 0, kind: Kind::Step, class: 1, seq: 0, a: 0, b: 0 });
        t.instant(2.0, Ev { node: 1, kind: Kind::Eval, class: 4, seq: 1, a: 0, b: 0 });
        assert_eq!(t.events_recorded(), 0);
        assert!(t.to_chrome_json().is_none());
        assert!(t.dump(None).unwrap().is_none());
        assert!(t.dump_if_requested().unwrap().is_none());
    }

    #[test]
    fn ring_keeps_the_last_n_events() {
        let spec = TraceSpec::parse("on,ring:4").unwrap();
        let mut t = Trace::from_spec(&spec, "ringtest");
        for i in 0..10u64 {
            t.instant(
                i as f64,
                Ev { node: 0, kind: Kind::Pop, class: 2, seq: i, a: i, b: 0 },
            );
        }
        assert_eq!(t.events_recorded(), 10);
        assert_eq!(t.events_held(), 4);
        let json_text = t.to_chrome_json().unwrap();
        // the survivors are seqs 6..=9, oldest first
        for kept in ["\"seq\":6", "\"seq\":7", "\"seq\":8", "\"seq\":9"] {
            assert!(json_text.contains(kept), "missing {kept} in {json_text}");
        }
        assert!(!json_text.contains("\"seq\":5"));
        let i6 = json_text.find("\"seq\":6").unwrap();
        let i9 = json_text.find("\"seq\":9").unwrap();
        assert!(i6 < i9, "ring must serialize oldest-first");
    }

    #[test]
    fn chrome_json_validates_and_is_deterministic() {
        let spec = TraceSpec::on();
        let emit = || {
            let mut t = Trace::from_spec(&spec, "det");
            t.span(0.0, 0.001, Ev { node: 0, kind: Kind::Step, class: 1, seq: 0, a: 7, b: 0 });
            t.span(0.001, 0.003, Ev { node: 0, kind: Kind::Flight, class: 2, seq: 1, a: 1, b: 48 });
            t.instant(0.003, Ev { node: 1, kind: Kind::Decode, class: 2, seq: 1, a: 48, b: 12 });
            t.to_chrome_json().unwrap()
        };
        let a = emit();
        let b = emit();
        assert_eq!(a, b, "same emissions must serialize byte-identically");
        let n = validate_chrome_trace(&a).unwrap();
        assert_eq!(n, 3, "metadata events are not counted");
        assert!(a.contains("\"ph\":\"X\""), "spans serialize as complete events");
        assert!(a.contains("\"ph\":\"i\""), "instants serialize as instant events");
    }

    #[test]
    fn validator_rejects_malformed_traces() {
        assert!(validate_chrome_trace("not json").is_err());
        assert!(validate_chrome_trace("{}").is_err());
        assert!(validate_chrome_trace(r#"{"traceEvents":[{"name":"x"}]}"#).is_err());
        assert!(
            validate_chrome_trace(r#"{"traceEvents":[{"name":"x","ph":"X","pid":0,"tid":0,"ts":1}]}"#)
                .is_err(),
            "complete event without dur must be rejected"
        );
        assert_eq!(
            validate_chrome_trace(
                r#"{"traceEvents":[{"name":"x","ph":"X","pid":0,"tid":0,"ts":1,"dur":2}]}"#
            )
            .unwrap(),
            1
        );
    }

    #[test]
    fn dump_writes_and_validates() {
        let dir = std::env::temp_dir().join(format!("eg-trace-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("flight.json");
        let mut t = Trace::from_spec(&TraceSpec::on(), "dumptest");
        t.instant(0.5, Ev { node: 2, kind: Kind::Churn, class: 0, seq: 3, a: 1, b: 0 });
        let written = t.dump(Some(&path)).unwrap().unwrap();
        assert_eq!(written, path);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(validate_chrome_trace(&text).unwrap(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wall_mode_attaches_wall_micros() {
        let spec = TraceSpec::parse("on,wall").unwrap();
        let mut t = Trace::from_spec(&spec, "wall");
        t.instant(0.0, Ev { node: 0, kind: Kind::Mark, class: 0, seq: 0, a: 0, b: 0 });
        let j = t.to_chrome_json().unwrap();
        assert!(j.contains("\"wall_us\":"), "{j}");
        // and the deterministic mode omits it entirely
        let mut t2 = Trace::from_spec(&TraceSpec::on(), "nowall");
        t2.instant(0.0, Ev { node: 0, kind: Kind::Mark, class: 0, seq: 0, a: 0, b: 0 });
        assert!(!t2.to_chrome_json().unwrap().contains("wall_us"));
    }

    #[test]
    fn percentiles_from_bucket_counts() {
        assert_eq!(percentile_bucket(&[0, 0, 0], 0.5), None);
        // 10 samples in bucket 1, 10 in bucket 3
        let counts = [0u64, 10, 0, 10];
        assert_eq!(percentile_bucket(&counts, 0.5), Some(1));
        assert_eq!(percentile_bucket(&counts, 0.51), Some(3));
        assert_eq!(percentile_bucket(&counts, 0.95), Some(3));
        assert_eq!(percentile_bucket(&counts, 0.0), Some(1));
        assert_eq!(percentile_bucket(&counts, 1.0), Some(3));
        // everything in one bucket
        assert_eq!(percentile_bucket(&[5], 0.99), Some(0));
    }

    #[test]
    fn zero_length_span_serializes_as_instant() {
        let mut t = Trace::from_spec(&TraceSpec::on(), "z");
        t.span(1.0, 1.0, Ev { node: 0, kind: Kind::Round, class: 3, seq: 0, a: 0, b: 0 });
        let j = t.to_chrome_json().unwrap();
        assert!(j.contains("\"ph\":\"i\""));
        assert_eq!(validate_chrome_trace(&j).unwrap(), 1);
    }
}
