//! Synthetic datasets, partitioning, and batching.
//!
//! The image has no network access, so MNIST / CIFAR-10 are replaced by
//! deterministic synthetic analogues (see DESIGN.md §4 — the experiments
//! compare *communication strategies*, whose dynamics depend on gradient
//! statistics and data partitioning, both of which the synthetic tasks
//! exercise; absolute accuracies differ from the paper, orderings and
//! curve shapes are what the harness reproduces).
//!
//! * `synthetic_mnist` — 10-class, 784-d, permutation-invariant: each
//!   class owns `MODES_PER_CLASS` anchor vectors (sub-modes, making the
//!   task non-linearly-separable); a sample is `anchor + sigma * noise`,
//!   globally standardized, exactly like the paper's pre-processing.
//! * `synthetic_cifar` — 10-class, 3x32x32 NHWC images built from
//!   class-dependent low-frequency sinusoid textures + noise.
//! * `synthetic_corpus` — byte corpus from a tiny deterministic grammar,
//!   for the LM end-to-end driver.
//!
//! Partitioning follows the paper's data-parallel setting: disjoint
//! shards per worker, IID by default, with a Dirichlet label-skew option
//! for the thesis's future-work question about biased collection.

pub mod formats;

use crate::util::rng::Rng;

pub const MODES_PER_CLASS: usize = 3;

/// Which workload family a dataset belongs to (decides the x dtype and
/// eval semantics downstream).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    /// f32 feature vectors / images, int class labels
    Classify,
    /// int token windows; label = next token (stored per-window)
    LanguageModel,
}

/// Feature storage: classification uses f32, LM uses i32 tokens.
#[derive(Clone, Debug)]
pub enum Features {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// An in-memory dataset of `n` instances with fixed-size features.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub kind: TaskKind,
    /// per-instance feature size (e.g. 784, 32*32*3, seq_len)
    pub feat: usize,
    pub features: Features,
    /// class label per instance (Classify) — for LM, `labels` holds the
    /// flattened next-token targets (n * feat entries) in `lm_targets`.
    pub labels: Vec<i32>,
    /// LM only: per-instance target windows, flattened
    pub lm_targets: Vec<i32>,
    pub classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        match &self.features {
            Features::F32(v) => v.len() / self.feat,
            Features::I32(v) => v.len() / self.feat,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Feature row `i` as f32 (panics for LM datasets).
    pub fn row_f32(&self, i: usize) -> &[f32] {
        match &self.features {
            Features::F32(v) => &v[i * self.feat..(i + 1) * self.feat],
            _ => panic!("row_f32 on token dataset"),
        }
    }

    pub fn row_i32(&self, i: usize) -> &[i32] {
        match &self.features {
            Features::I32(v) => &v[i * self.feat..(i + 1) * self.feat],
            _ => panic!("row_i32 on float dataset"),
        }
    }

    /// Split into (train, val, test) by counts, deterministically shuffled.
    pub fn split(&self, n_train: usize, n_val: usize, n_test: usize, rng: &mut Rng) -> (Dataset, Dataset, Dataset) {
        assert!(n_train + n_val + n_test <= self.len());
        let mut idx: Vec<usize> = (0..self.len()).collect();
        rng.shuffle(&mut idx);
        let take = |range: std::ops::Range<usize>| self.subset(&idx[range]);
        (
            take(0..n_train),
            take(n_train..n_train + n_val),
            take(n_train + n_val..n_train + n_val + n_test),
        )
    }

    /// Materialize a subset by instance indices.
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let mut labels = Vec::with_capacity(idx.len());
        let mut lm_targets = Vec::new();
        let features = match &self.features {
            Features::F32(_) => {
                let mut f = Vec::with_capacity(idx.len() * self.feat);
                for &i in idx {
                    f.extend_from_slice(self.row_f32(i));
                    labels.push(self.labels[i]);
                }
                Features::F32(f)
            }
            Features::I32(_) => {
                let mut f = Vec::with_capacity(idx.len() * self.feat);
                for &i in idx {
                    f.extend_from_slice(self.row_i32(i));
                    if !self.labels.is_empty() {
                        labels.push(self.labels[i]);
                    }
                    lm_targets.extend_from_slice(
                        &self.lm_targets[i * self.feat..(i + 1) * self.feat],
                    );
                }
                Features::I32(f)
            }
        };
        Dataset {
            kind: self.kind,
            feat: self.feat,
            features,
            labels,
            lm_targets,
            classes: self.classes,
        }
    }
}

// ---------------------------------------------------------------------------
// generators
// ---------------------------------------------------------------------------

/// Synthetic permutation-invariant MNIST analogue (see module docs).
///
/// Difficulty knobs chosen so the paper MLP separates the task well but
/// not instantly: anchors at radius ~2.2 in whitened space, noise sigma
/// 1.0, 3 sub-modes per class.
pub fn synthetic_mnist(n: usize, seed: u64) -> Dataset {
    synthetic_vectors(n, 784, 10, seed ^ 0x139A)
}

/// Generic clustered-Gaussian classification task.
pub fn synthetic_vectors(n: usize, dim: usize, classes: usize, seed: u64) -> Dataset {
    let mut anchor_rng = Rng::new(seed ^ 0xA17C);
    // class/mode anchors: unit Gaussian directions scaled to fixed radius
    let radius = 2.2f32;
    let mut anchors = vec![0.0f32; classes * MODES_PER_CLASS * dim];
    for a in anchors.chunks_exact_mut(dim) {
        let mut norm = 0.0f64;
        for x in a.iter_mut() {
            *x = anchor_rng.gauss_f32();
            norm += (*x as f64) * (*x as f64);
        }
        let s = radius / (norm.sqrt() as f32 / (dim as f32).sqrt());
        // scale so per-coordinate anchor magnitude ~ radius/sqrt(dim)... keep
        // overall SNR constant across dim
        let s = s / (dim as f32).sqrt();
        a.iter_mut().for_each(|x| *x *= s);
    }

    let mut rng = Rng::new(seed);
    let mut features = vec![0.0f32; n * dim];
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let y = i % classes; // balanced
        let mode = rng.below(MODES_PER_CLASS);
        let a = &anchors[(y * MODES_PER_CLASS + mode) * dim..][..dim];
        let row = &mut features[i * dim..(i + 1) * dim];
        for (r, &av) in row.iter_mut().zip(a.iter()) {
            *r = av + 0.35 * rng.gauss_f32();
        }
        labels.push(y as i32);
    }
    standardize(&mut features, dim);
    Dataset {
        kind: TaskKind::Classify,
        feat: dim,
        features: Features::F32(features),
        labels,
        lm_targets: Vec::new(),
        classes,
    }
}

/// Synthetic CIFAR-10 analogue: 32x32x3 NHWC low-frequency textures.
pub fn synthetic_cifar(n: usize, seed: u64) -> Dataset {
    let (h, w, c) = (32usize, 32usize, 3usize);
    let dim = h * w * c;
    let classes = 10;
    let mut frq_rng = Rng::new(seed ^ 0xC1FA);
    // each class: 3 sinusoid components (fx, fy, phase-channel weights)
    struct Comp {
        fx: f32,
        fy: f32,
        ch: [f32; 3],
    }
    let comps: Vec<Vec<Comp>> = (0..classes)
        .map(|_| {
            (0..3)
                .map(|_| Comp {
                    fx: 1.0 + 3.0 * frq_rng.f32(),
                    fy: 1.0 + 3.0 * frq_rng.f32(),
                    ch: [frq_rng.gauss_f32(), frq_rng.gauss_f32(), frq_rng.gauss_f32()],
                })
                .collect()
        })
        .collect();

    let mut rng = Rng::new(seed);
    let mut features = vec![0.0f32; n * dim];
    let mut labels = Vec::with_capacity(n);
    let tau = std::f32::consts::TAU;
    for i in 0..n {
        let y = i % classes;
        let row = &mut features[i * dim..(i + 1) * dim];
        let phase: Vec<f32> = (0..3).map(|_| tau * rng.f32()).collect();
        for (ci, comp) in comps[y].iter().enumerate() {
            for yy in 0..h {
                for xx in 0..w {
                    let v = (comp.fx * xx as f32 / w as f32 * tau
                        + comp.fy * yy as f32 / h as f32 * tau
                        + phase[ci])
                        .sin();
                    for ch in 0..c {
                        row[(yy * w + xx) * c + ch] += comp.ch[ch] * v;
                    }
                }
            }
        }
        for r in row.iter_mut() {
            *r += 0.4 * rng.gauss_f32();
        }
        labels.push(y as i32);
    }
    standardize(&mut features, dim);
    Dataset {
        kind: TaskKind::Classify,
        feat: dim,
        features: Features::F32(features),
        labels,
        lm_targets: Vec::new(),
        classes,
    }
}

/// Synthetic byte corpus: windows from text generated by a tiny grammar
/// (deterministic in seed).  Instance = `seq` input bytes; targets =
/// next-byte at each position.
pub fn synthetic_corpus(n_windows: usize, seq: usize, seed: u64) -> Dataset {
    let subjects = ["the gossip", "a worker", "the consensus", "every replica", "the gradient"];
    let verbs = ["averages", "updates", "anneals", "converges to", "drifts from", "pulls"];
    let objects = [
        "the center variable",
        "its peer",
        "the moving rate",
        "a local optimum",
        "the parameter space",
        "the communication period",
    ];
    let mut rng = Rng::new(seed);
    let need = n_windows * (seq + 1) + seq;
    let mut text = Vec::with_capacity(need + 64);
    while text.len() < need {
        let s = format!(
            "{} {} {}. ",
            rng.choose(&subjects),
            rng.choose(&verbs),
            rng.choose(&objects)
        );
        text.extend_from_slice(s.as_bytes());
    }
    let mut features = Vec::with_capacity(n_windows * seq);
    let mut targets = Vec::with_capacity(n_windows * seq);
    for i in 0..n_windows {
        let off = i * (seq + 1) % (text.len() - seq - 1);
        for j in 0..seq {
            features.push(text[off + j] as i32);
            targets.push(text[off + j + 1] as i32);
        }
    }
    Dataset {
        kind: TaskKind::LanguageModel,
        feat: seq,
        features: Features::I32(features),
        labels: Vec::new(),
        lm_targets: targets,
        classes: 256,
    }
}

/// Zero-mean / unit-variance per feature across the whole set (the
/// paper's §4.1/§4.2 pre-processing).
pub fn standardize(features: &mut [f32], dim: usize) {
    let n = features.len() / dim;
    if n == 0 {
        return;
    }
    for d in 0..dim {
        let mut mean = 0.0f64;
        for i in 0..n {
            mean += features[i * dim + d] as f64;
        }
        mean /= n as f64;
        let mut var = 0.0f64;
        for i in 0..n {
            let v = features[i * dim + d] as f64 - mean;
            var += v * v;
        }
        var /= n as f64;
        let inv = 1.0 / var.sqrt().max(1e-8);
        for i in 0..n {
            let v = &mut features[i * dim + d];
            *v = ((*v as f64 - mean) * inv) as f32;
        }
    }
}

// ---------------------------------------------------------------------------
// partitioning
// ---------------------------------------------------------------------------

/// How training data is spread across workers.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Partition {
    /// Disjoint IID shards (the paper's setting).
    Iid,
    /// Dirichlet(beta) label skew — smaller beta = more biased shards
    /// (the thesis's future-work condition).
    DirichletSkew { beta: f64 },
}

impl Partition {
    /// Assign instance indices to `w` workers. Every instance is assigned
    /// to exactly one worker.
    pub fn assign(&self, ds: &Dataset, w: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
        assert!(w >= 1);
        let n = ds.len();
        match self {
            Partition::Iid => {
                let mut idx: Vec<usize> = (0..n).collect();
                rng.shuffle(&mut idx);
                let mut shards = vec![Vec::with_capacity(n / w + 1); w];
                for (pos, &i) in idx.iter().enumerate() {
                    shards[pos % w].push(i);
                }
                shards
            }
            Partition::DirichletSkew { beta } => {
                // per-class worker distribution ~ Dirichlet(beta)
                let classes = ds.classes.max(1);
                let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); classes];
                for i in 0..n {
                    let y = if ds.labels.is_empty() { 0 } else { ds.labels[i] as usize };
                    by_class[y % classes].push(i);
                }
                let mut shards = vec![Vec::new(); w];
                for idxs in by_class.iter_mut() {
                    rng.shuffle(idxs);
                    let p = rng.dirichlet(*beta, w);
                    // convert proportions to contiguous counts
                    let mut counts: Vec<usize> =
                        p.iter().map(|&q| (q * idxs.len() as f64) as usize).collect();
                    let assigned: usize = counts.iter().sum();
                    // distribute the remainder round-robin by largest share
                    let mut order: Vec<usize> = (0..w).collect();
                    order.sort_by(|&a, &b| p[b].partial_cmp(&p[a]).unwrap());
                    for r in 0..idxs.len() - assigned {
                        counts[order[r % w]] += 1;
                    }
                    let mut off = 0;
                    for (wi, &c) in counts.iter().enumerate() {
                        shards[wi].extend_from_slice(&idxs[off..off + c]);
                        off += c;
                    }
                }
                shards
            }
        }
    }
}

// ---------------------------------------------------------------------------
// batching
// ---------------------------------------------------------------------------

/// Epoch-reshuffling mini-batch cursor over a worker's shard.
///
/// Yields fixed-size batches (required: AOT artifacts are shape-
/// specialized); the tail that doesn't fill a batch carries over into the
/// next epoch pass, matching "sampling without replacement per epoch".
#[derive(Clone, Debug)]
pub struct BatchCursor {
    order: Vec<usize>,
    pos: usize,
    rng: Rng,
}

impl BatchCursor {
    pub fn new(shard: Vec<usize>, rng: Rng) -> Self {
        let mut c = BatchCursor { order: shard, pos: 0, rng };
        c.reshuffle();
        c
    }

    fn reshuffle(&mut self) {
        let mut r = self.rng.clone();
        r.shuffle(&mut self.order);
        self.rng = r;
        self.pos = 0;
    }

    /// Next `b` instance indices (reshuffles on wrap).
    pub fn next_batch(&mut self, b: usize, out: &mut Vec<usize>) {
        out.clear();
        while out.len() < b {
            if self.pos >= self.order.len() {
                self.reshuffle();
            }
            let take = (b - out.len()).min(self.order.len() - self.pos);
            out.extend_from_slice(&self.order[self.pos..self.pos + take]);
            self.pos += take;
        }
    }

    pub fn shard_len(&self) -> usize {
        self.order.len()
    }

    /// Churn-aware shard reassignment: fold `extra` instance indices
    /// (a confirmed-dead peer's shard slice) into this cursor's shard.
    /// Appended past the cursor, so the current epoch pass finishes its
    /// own draw order; the adopted rows mix in from the next reshuffle.
    pub fn adopt(&mut self, extra: &[usize]) {
        self.order.extend_from_slice(extra);
    }

    /// Undo an adoption (the dead peer rejoined and takes its shard
    /// back): remove one occurrence of each index in `gone`, keeping the
    /// cursor position consistent with the surviving draw order.
    pub fn evict(&mut self, gone: &[usize]) {
        for &g in gone {
            if let Some(idx) = self.order.iter().position(|&x| x == g) {
                self.order.remove(idx);
                if idx < self.pos {
                    self.pos -= 1;
                }
            }
        }
        self.pos = self.pos.min(self.order.len());
    }
}

/// Pack batch `idx` rows of `ds` into flat buffers for the engine.
pub fn gather_f32(ds: &Dataset, idx: &[usize], x_out: &mut Vec<f32>, y_out: &mut Vec<i32>) {
    x_out.clear();
    y_out.clear();
    for &i in idx {
        x_out.extend_from_slice(ds.row_f32(i));
        y_out.push(ds.labels[i]);
    }
}

/// LM variant: inputs + per-position targets.
pub fn gather_i32(ds: &Dataset, idx: &[usize], x_out: &mut Vec<i32>, y_out: &mut Vec<i32>) {
    x_out.clear();
    y_out.clear();
    for &i in idx {
        x_out.extend_from_slice(ds.row_i32(i));
        y_out.extend_from_slice(&ds.lm_targets[i * ds.feat..(i + 1) * ds.feat]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mnist_shape_and_standardization() {
        let ds = synthetic_mnist(500, 7);
        assert_eq!(ds.len(), 500);
        assert_eq!(ds.feat, 784);
        assert_eq!(ds.classes, 10);
        // standardized: global mean ~0, var ~1
        let f = match &ds.features {
            Features::F32(v) => v,
            _ => unreachable!(),
        };
        let m: f64 = f.iter().map(|&x| x as f64).sum::<f64>() / f.len() as f64;
        let v: f64 = f.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / f.len() as f64;
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn deterministic_generation() {
        let a = synthetic_mnist(100, 3);
        let b = synthetic_mnist(100, 3);
        assert_eq!(a.labels, b.labels);
        if let (Features::F32(fa), Features::F32(fb)) = (&a.features, &b.features) {
            assert_eq!(fa, fb);
        }
        let c = synthetic_mnist(100, 4);
        if let (Features::F32(fa), Features::F32(fc)) = (&a.features, &c.features) {
            assert_ne!(fa, fc);
        }
    }

    #[test]
    fn classes_balanced() {
        let ds = synthetic_mnist(1000, 1);
        let mut counts = [0usize; 10];
        for &y in &ds.labels {
            counts[y as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 100));
    }

    #[test]
    fn cifar_shape() {
        let ds = synthetic_cifar(50, 2);
        assert_eq!(ds.feat, 32 * 32 * 3);
        assert_eq!(ds.len(), 50);
    }

    #[test]
    fn corpus_next_byte_alignment() {
        let ds = synthetic_corpus(20, 16, 5);
        assert_eq!(ds.kind, TaskKind::LanguageModel);
        assert_eq!(ds.len(), 20);
        // target[j] must equal input[j+1] within a window
        let x = ds.row_i32(3);
        let t = &ds.lm_targets[3 * 16..4 * 16];
        for j in 0..15 {
            assert_eq!(t[j], x[j + 1]);
        }
    }

    #[test]
    fn split_disjoint_and_sized() {
        let ds = synthetic_mnist(300, 1);
        let mut rng = Rng::new(0);
        let (tr, va, te) = ds.split(200, 50, 50, &mut rng);
        assert_eq!((tr.len(), va.len(), te.len()), (200, 50, 50));
    }

    #[test]
    fn iid_partition_complete_and_disjoint() {
        let ds = synthetic_mnist(103, 1);
        let mut rng = Rng::new(0);
        let shards = Partition::Iid.assign(&ds, 4, &mut rng);
        let mut all: Vec<usize> = shards.concat();
        all.sort();
        assert_eq!(all, (0..103).collect::<Vec<_>>());
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn dirichlet_partition_complete_and_skewed() {
        let ds = synthetic_mnist(1000, 1);
        let mut rng = Rng::new(0);
        let shards = Partition::DirichletSkew { beta: 0.1 }.assign(&ds, 4, &mut rng);
        let mut all: Vec<usize> = shards.concat();
        all.sort();
        assert_eq!(all.len(), 1000);
        all.dedup();
        assert_eq!(all.len(), 1000);
        // skew: at least one worker's class distribution is far from uniform
        let mut max_frac = 0.0f64;
        for s in &shards {
            if s.is_empty() {
                continue;
            }
            let mut counts = [0usize; 10];
            for &i in s {
                counts[ds.labels[i] as usize] += 1;
            }
            let top = *counts.iter().max().unwrap() as f64 / s.len() as f64;
            max_frac = max_frac.max(top);
        }
        assert!(max_frac > 0.25, "beta=0.1 should skew ({max_frac})");
    }

    #[test]
    fn batch_cursor_fixed_size_and_coverage() {
        let cursor_rng = Rng::new(9);
        let mut c = BatchCursor::new((0..10).collect(), cursor_rng);
        let mut batch = Vec::new();
        let mut seen = vec![0usize; 10];
        for _ in 0..5 {
            c.next_batch(4, &mut batch);
            assert_eq!(batch.len(), 4);
            for &i in &batch {
                seen[i] += 1;
            }
        }
        // 20 draws over 10 items: each item seen exactly twice
        assert!(seen.iter().all(|&s| s == 2), "{seen:?}");
    }

    #[test]
    fn batch_cursor_adopt_then_evict_restores_shard() {
        let mut c = BatchCursor::new((0..8).collect(), Rng::new(11));
        let mut batch = Vec::new();
        c.next_batch(3, &mut batch); // pos = 3 mid-pass
        let mirror = c.clone();
        c.adopt(&[20, 21, 22]);
        assert_eq!(c.shard_len(), 11);
        // the adopted rows appear once the pass wraps: draw everything
        let mut seen = vec![0usize; 23];
        for _ in 0..11 {
            c.next_batch(2, &mut batch);
            for &i in &batch {
                seen[i] += 1;
            }
        }
        assert!((0..8).all(|i| seen[i] >= 1), "{seen:?}");
        assert!([20, 21, 22].iter().all(|&i| seen[i] >= 1), "adopted rows never drawn: {seen:?}");
        c.evict(&[20, 21, 22]);
        assert_eq!(c.shard_len(), 8);
        assert!(!c.order.contains(&20) && !c.order.contains(&21) && !c.order.contains(&22));
        // evict of untouched indices is a no-op; the mirror is unaffected
        c.evict(&[99]);
        assert_eq!(c.shard_len(), 8);
        assert_eq!(mirror.shard_len(), 8);
    }

    #[test]
    fn batch_cursor_evict_before_position_keeps_draw_order() {
        let mut c = BatchCursor::new((0..6).collect(), Rng::new(3));
        let mut batch = Vec::new();
        c.next_batch(4, &mut batch); // pos = 4
        let upcoming = c.order[c.pos..].to_vec();
        let victim = c.order[1]; // already drawn this pass
        c.evict(&[victim]);
        assert_eq!(c.order[c.pos..], upcoming[..], "undrawn tail must survive eviction");
        c.next_batch(2, &mut batch); // drains the tail + wraps cleanly
        assert_eq!(c.shard_len(), 5);
    }

    #[test]
    fn gather_packs_rows() {
        let ds = synthetic_vectors(10, 4, 3, 0);
        let (mut x, mut y) = (Vec::new(), Vec::new());
        gather_f32(&ds, &[2, 5], &mut x, &mut y);
        assert_eq!(x.len(), 8);
        assert_eq!(y, vec![ds.labels[2], ds.labels[5]]);
        assert_eq!(&x[0..4], ds.row_f32(2));
    }
}
