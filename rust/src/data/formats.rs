//! Real-dataset file formats: IDX (MNIST) and the CIFAR-10 binary format.
//!
//! The container image has no network access, so the shipped experiments
//! run on synthetic analogues (see module docs of `data`) — but a
//! downstream user with the actual files gets the paper-faithful path:
//!
//! * `load_mnist(dir)` reads `train-images-idx3-ubyte` /
//!   `train-labels-idx1-ubyte` (+ `t10k-*`), the LeCun IDX format
//!   (big-endian magic, dims, raw u8 payload), flattens to 784-d f32 and
//!   applies the paper's global standardization.
//! * `load_cifar10(dir)` reads `data_batch_{1..5}.bin` + `test_batch.bin`
//!   (1 label byte + 3072 CHW pixel bytes per record), converts to NHWC
//!   f32 and standardizes.
//!
//! Both parsers are fully unit-tested against synthetic files written in
//! the exact on-disk format.

use anyhow::{bail, ensure, Context, Result};
use std::path::Path;

use super::{standardize, Dataset, Features, TaskKind};

// ---------------------------------------------------------------------------
// IDX (MNIST)
// ---------------------------------------------------------------------------

/// A parsed IDX file: dimensions + raw u8 payload.
#[derive(Debug)]
pub struct IdxFile {
    pub dims: Vec<usize>,
    pub data: Vec<u8>,
}

/// Parse the IDX format: `[0, 0, dtype, ndims, dim0_be_u32, ..., payload]`.
/// Only `dtype = 0x08` (unsigned byte) is supported — that is what MNIST
/// uses.  Accepts an optional gzip wrapper (magic 0x1f8b) since the
/// files are usually distributed gzipped.
pub fn parse_idx(bytes: &[u8]) -> Result<IdxFile> {
    let bytes = if bytes.len() >= 2 && bytes[0] == 0x1f && bytes[1] == 0x8b {
        gunzip(bytes).context("gunzip idx")?
    } else {
        bytes.to_vec()
    };
    ensure!(bytes.len() >= 4, "idx: truncated header");
    ensure!(bytes[0] == 0 && bytes[1] == 0, "idx: bad magic");
    let dtype = bytes[2];
    ensure!(dtype == 0x08, "idx: unsupported dtype {dtype:#x} (only u8)");
    let ndims = bytes[3] as usize;
    ensure!(ndims >= 1 && ndims <= 4, "idx: implausible ndims {ndims}");
    ensure!(bytes.len() >= 4 + 4 * ndims, "idx: truncated dims");
    let mut dims = Vec::with_capacity(ndims);
    for d in 0..ndims {
        let off = 4 + 4 * d;
        dims.push(u32::from_be_bytes([
            bytes[off],
            bytes[off + 1],
            bytes[off + 2],
            bytes[off + 3],
        ]) as usize);
    }
    let expect: usize = dims.iter().product();
    let payload = &bytes[4 + 4 * ndims..];
    ensure!(
        payload.len() == expect,
        "idx: payload {} != product(dims) {}",
        payload.len(),
        expect
    );
    Ok(IdxFile { dims, data: payload.to_vec() })
}

/// Minimal DEFLATE-wrapper decompressor is out of scope for this crate's
/// vendored set; gzip files must be decompressed by the user first.
fn gunzip(_bytes: &[u8]) -> Result<Vec<u8>> {
    bail!("gzipped idx files are not supported — `gunzip` them first")
}

/// Load MNIST train+test from `dir` into one `Dataset` (train first,
/// then test; callers split by count). Expects the four standard
/// (un-gzipped) files.
pub fn load_mnist(dir: impl AsRef<Path>) -> Result<Dataset> {
    let dir = dir.as_ref();
    let mut features = Vec::new();
    let mut labels = Vec::new();
    for (img, lab) in [
        ("train-images-idx3-ubyte", "train-labels-idx1-ubyte"),
        ("t10k-images-idx3-ubyte", "t10k-labels-idx1-ubyte"),
    ] {
        let images = parse_idx(&std::fs::read(dir.join(img)).with_context(|| img.to_string())?)?;
        let labs = parse_idx(&std::fs::read(dir.join(lab)).with_context(|| lab.to_string())?)?;
        ensure!(images.dims.len() == 3, "images must be n x h x w");
        ensure!(labs.dims.len() == 1, "labels must be 1-d");
        let (n, h, w) = (images.dims[0], images.dims[1], images.dims[2]);
        ensure!(labs.dims[0] == n, "image/label count mismatch");
        ensure!(h * w == 784, "expected 28x28 images");
        features.extend(images.data.iter().map(|&b| b as f32 / 255.0));
        labels.extend(labs.data.iter().map(|&b| b as i32));
    }
    standardize(&mut features, 784);
    Ok(Dataset {
        kind: TaskKind::Classify,
        feat: 784,
        features: Features::F32(features),
        labels,
        lm_targets: Vec::new(),
        classes: 10,
    })
}

// ---------------------------------------------------------------------------
// CIFAR-10 binary
// ---------------------------------------------------------------------------

const CIFAR_REC: usize = 1 + 3072; // label + 32*32*3 (CHW)

/// Parse one CIFAR-10 binary batch: records of `[label, 3072 x u8 CHW]`.
/// Output features are NHWC f32 in [0,1] (standardization is applied by
/// `load_cifar10` across the full set).
pub fn parse_cifar_batch(bytes: &[u8], features: &mut Vec<f32>, labels: &mut Vec<i32>) -> Result<usize> {
    ensure!(
        bytes.len() % CIFAR_REC == 0,
        "cifar batch not a multiple of {CIFAR_REC} bytes"
    );
    let n = bytes.len() / CIFAR_REC;
    for r in 0..n {
        let rec = &bytes[r * CIFAR_REC..(r + 1) * CIFAR_REC];
        let label = rec[0];
        ensure!(label < 10, "cifar label {label} out of range");
        labels.push(label as i32);
        let pix = &rec[1..];
        // CHW -> HWC
        for y in 0..32 {
            for x in 0..32 {
                for c in 0..3 {
                    features.push(pix[c * 1024 + y * 32 + x] as f32 / 255.0);
                }
            }
        }
    }
    Ok(n)
}

/// Load CIFAR-10 from the standard `cifar-10-batches-bin` layout.
pub fn load_cifar10(dir: impl AsRef<Path>) -> Result<Dataset> {
    let dir = dir.as_ref();
    let mut features = Vec::new();
    let mut labels = Vec::new();
    let mut files: Vec<String> = (1..=5).map(|i| format!("data_batch_{i}.bin")).collect();
    files.push("test_batch.bin".into());
    for f in files {
        let bytes = std::fs::read(dir.join(&f)).with_context(|| f.clone())?;
        parse_cifar_batch(&bytes, &mut features, &mut labels)?;
    }
    standardize(&mut features, 3072);
    Ok(Dataset {
        kind: TaskKind::Classify,
        feat: 3072,
        features: Features::F32(features),
        labels,
        lm_targets: Vec::new(),
        classes: 10,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn make_idx(dims: &[usize], payload: &[u8]) -> Vec<u8> {
        let mut out = vec![0, 0, 0x08, dims.len() as u8];
        for &d in dims {
            out.extend_from_slice(&(d as u32).to_be_bytes());
        }
        out.extend_from_slice(payload);
        out
    }

    #[test]
    fn idx_roundtrip() {
        let payload: Vec<u8> = (0..24).collect();
        let f = parse_idx(&make_idx(&[2, 3, 4], &payload)).unwrap();
        assert_eq!(f.dims, vec![2, 3, 4]);
        assert_eq!(f.data, payload);
    }

    #[test]
    fn idx_rejects_garbage() {
        assert!(parse_idx(&[]).is_err());
        assert!(parse_idx(&[1, 0, 8, 1, 0, 0, 0, 0]).is_err()); // bad magic
        assert!(parse_idx(&make_idx(&[5], &[0; 4])).is_err()); // short payload
        let mut f = make_idx(&[2], &[0, 1]);
        f[2] = 0x0D; // float dtype
        assert!(parse_idx(&f).is_err());
    }

    #[test]
    fn mnist_loader_end_to_end() {
        let dir = std::env::temp_dir().join(format!("eg-mnist-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // 3 train + 2 test images of 28x28
        let imgs = |n: usize, base: u8| -> Vec<u8> {
            (0..n * 784).map(|i| (base as usize + i % 251) as u8).collect()
        };
        std::fs::write(dir.join("train-images-idx3-ubyte"), make_idx(&[3, 28, 28], &imgs(3, 0))).unwrap();
        std::fs::write(dir.join("train-labels-idx1-ubyte"), make_idx(&[3], &[1, 7, 3])).unwrap();
        std::fs::write(dir.join("t10k-images-idx3-ubyte"), make_idx(&[2, 28, 28], &imgs(2, 9))).unwrap();
        std::fs::write(dir.join("t10k-labels-idx1-ubyte"), make_idx(&[2], &[0, 9])).unwrap();
        let ds = load_mnist(&dir).unwrap();
        assert_eq!(ds.len(), 5);
        assert_eq!(ds.feat, 784);
        assert_eq!(ds.labels, vec![1, 7, 3, 0, 9]);
        // standardized: finite, roughly zero-mean
        let f = match &ds.features {
            Features::F32(v) => v,
            _ => unreachable!(),
        };
        assert!(f.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn mnist_loader_detects_count_mismatch() {
        let dir = std::env::temp_dir().join(format!("eg-mnist-bad-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("train-images-idx3-ubyte"), make_idx(&[2, 28, 28], &vec![0; 2 * 784])).unwrap();
        std::fs::write(dir.join("train-labels-idx1-ubyte"), make_idx(&[3], &[0, 1, 2])).unwrap();
        std::fs::write(dir.join("t10k-images-idx3-ubyte"), make_idx(&[1, 28, 28], &vec![0; 784])).unwrap();
        std::fs::write(dir.join("t10k-labels-idx1-ubyte"), make_idx(&[1], &[0])).unwrap();
        assert!(load_mnist(&dir).is_err());
    }

    #[test]
    fn cifar_batch_chw_to_hwc() {
        // one record: label 4, pixel (y=0,x=1) has R=10,G=20,B=30
        let mut rec = vec![0u8; CIFAR_REC];
        rec[0] = 4;
        rec[1 + 0 * 1024 + 0 * 32 + 1] = 10; // R channel
        rec[1 + 1 * 1024 + 0 * 32 + 1] = 20; // G
        rec[1 + 2 * 1024 + 0 * 32 + 1] = 30; // B
        let mut f = Vec::new();
        let mut l = Vec::new();
        assert_eq!(parse_cifar_batch(&rec, &mut f, &mut l).unwrap(), 1);
        assert_eq!(l, vec![4]);
        // NHWC: pixel (0,1) occupies indices [3..6)
        assert!((f[3] - 10.0 / 255.0).abs() < 1e-6);
        assert!((f[4] - 20.0 / 255.0).abs() < 1e-6);
        assert!((f[5] - 30.0 / 255.0).abs() < 1e-6);
    }

    #[test]
    fn cifar_batch_rejects_bad_sizes_and_labels() {
        let mut f = Vec::new();
        let mut l = Vec::new();
        assert!(parse_cifar_batch(&[0; 100], &mut f, &mut l).is_err());
        let mut rec = vec![0u8; CIFAR_REC];
        rec[0] = 11;
        assert!(parse_cifar_batch(&rec, &mut f, &mut l).is_err());
    }
}
