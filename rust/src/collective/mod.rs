//! All-reduce implementations over the comm fabric.
//!
//! §2.1.1 of the thesis surveys three generations of all-reduce system
//! architecture; we implement all three so the benches can reproduce the
//! communication-scaling argument:
//!
//! * **Central** — a parameter-server-style reduce: everyone sends to
//!   rank 0, rank 0 broadcasts the mean.  Per-worker traffic `O(n)`,
//!   rank-0 traffic `O(W·n)` (the bottleneck the paper calls out).
//! * **Tree** — recursive halving/doubling; `O(log W)` rounds.
//! * **Ring** — Patarasuk & Yuan bandwidth-optimal ring: per-worker
//!   traffic `2·n·(W-1)/W` independent of W (the "cluster-size
//!   independent scaling of ring-reduce", §2.4).
//!
//! All three compute the elementwise **mean** across workers' buffers and
//! leave every worker with an identical copy, matching Algorithm 1 line 4.
//! The reductions operate on the actual data (the simulation moves real
//! bytes), and every transfer is accounted through the fabric.

use crate::comm::Fabric;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllReduceImpl {
    Central,
    Tree,
    Ring,
}

impl AllReduceImpl {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "central" => AllReduceImpl::Central,
            "tree" => AllReduceImpl::Tree,
            "ring" => AllReduceImpl::Ring,
            other => anyhow::bail!("unknown allreduce impl {other:?}"),
        })
    }

    /// Average `bufs` (one per worker, equal lengths) in place; all end
    /// identical. Transfers accounted via `fabric`.
    pub fn all_reduce_mean(&self, bufs: &mut [Vec<f32>], fabric: &mut Fabric) {
        let w = bufs.len();
        if w <= 1 {
            return;
        }
        let n = bufs[0].len();
        assert!(bufs.iter().all(|b| b.len() == n), "ragged all-reduce buffers");
        match self {
            AllReduceImpl::Central => central(bufs, fabric),
            AllReduceImpl::Tree => tree(bufs, fabric),
            AllReduceImpl::Ring => ring(bufs, fabric),
        }
    }

    /// Closed-form bytes a single worker sends for a buffer of `n` f32s
    /// across `w` workers (used by tests and the comm-cost bench).
    pub fn bytes_sent_per_worker(&self, n: usize, w: usize, rank: usize) -> u64 {
        if w <= 1 {
            return 0;
        }
        let nb = (n * 4) as u64;
        match self {
            AllReduceImpl::Central => {
                if rank == 0 {
                    nb * (w as u64 - 1) // broadcast
                } else {
                    nb // send to root
                }
            }
            AllReduceImpl::Tree => {
                // reduce up + broadcast down: each non-root sends once up,
                // each internal node sends down to its children
                let mut sent = 0u64;
                // halving (reduce): pairs at distances 1,2,4...
                let mut d = 1;
                while d < w {
                    if rank % (2 * d) == d && rank.saturating_sub(d) % (2 * d) == 0 {
                        sent += nb;
                    }
                    d *= 2;
                }
                // doubling (broadcast): root path sends
                let mut d = largest_pow2_below(w);
                while d >= 1 {
                    if rank % (2 * d) == 0 && rank + d < w {
                        sent += nb;
                    }
                    if d == 1 {
                        break;
                    }
                    d /= 2;
                }
                sent
            }
            AllReduceImpl::Ring => {
                // 2(w-1) chunk sends of ~n/w elements each
                let chunks = chunk_sizes(n, w);
                let mut sent = 0u64;
                for step in 0..2 * (w - 1) {
                    let c = (rank + w - step % w) % w; // chunk index cycles
                    sent += (chunks[c % w] * 4) as u64;
                }
                sent
            }
        }
    }
}

fn largest_pow2_below(w: usize) -> usize {
    let mut d = 1;
    while d * 2 < w {
        d *= 2;
    }
    d
}

/// Split n elements into w contiguous chunks, sizes differing by <= 1.
fn chunk_sizes(n: usize, w: usize) -> Vec<usize> {
    let base = n / w;
    let extra = n % w;
    (0..w).map(|i| base + usize::from(i < extra)).collect()
}

fn chunk_bounds(n: usize, w: usize) -> Vec<(usize, usize)> {
    let sizes = chunk_sizes(n, w);
    let mut out = Vec::with_capacity(w);
    let mut off = 0;
    for s in sizes {
        out.push((off, off + s));
        off += s;
    }
    out
}

// ---------------------------------------------------------------------------

fn central(bufs: &mut [Vec<f32>], fabric: &mut Fabric) {
    let w = bufs.len();
    let n = bufs[0].len();
    // gather: everyone sends to rank 0, which accumulates
    let (root, rest) = bufs.split_first_mut().unwrap();
    for (j, b) in rest.iter().enumerate() {
        fabric.send_params(j + 1, 0, n);
        for (r, &x) in root.iter_mut().zip(b.iter()) {
            *r += x;
        }
    }
    let inv = 1.0 / w as f32;
    root.iter_mut().for_each(|x| *x *= inv);
    // broadcast
    for (j, b) in rest.iter_mut().enumerate() {
        fabric.send_params(0, j + 1, n);
        b.copy_from_slice(root);
    }
}

fn tree(bufs: &mut [Vec<f32>], fabric: &mut Fabric) {
    let w = bufs.len();
    let n = bufs[0].len();
    // reduce (halving): at distance d, rank r+d sends into rank r for r % 2d == 0
    let mut d = 1;
    while d < w {
        let mut r = 0;
        while r + d < w {
            if r % (2 * d) == 0 {
                fabric.send_params(r + d, r, n);
                let (lo, hi) = bufs.split_at_mut(r + d);
                for (a, &b) in lo[r].iter_mut().zip(hi[0].iter()) {
                    *a += b;
                }
            }
            r += 2 * d;
        }
        d *= 2;
    }
    let inv = 1.0 / w as f32;
    bufs[0].iter_mut().for_each(|x| *x *= inv);
    // broadcast (doubling)
    let mut d = largest_pow2_below(w);
    loop {
        let mut r = 0;
        while r < w {
            if r % (2 * d) == 0 && r + d < w {
                fabric.send_params(r, r + d, n);
                let (lo, hi) = bufs.split_at_mut(r + d);
                let src = lo[r].clone();
                hi[0].copy_from_slice(&src);
            }
            r += 2 * d;
        }
        if d == 1 {
            break;
        }
        d /= 2;
    }
}

fn ring(bufs: &mut [Vec<f32>], fabric: &mut Fabric) {
    let w = bufs.len();
    let n = bufs[0].len();
    let bounds = chunk_bounds(n, w);

    // Phase 1: reduce-scatter. In step s, worker i sends chunk (i - s) to
    // worker (i+1), which accumulates. After w-1 steps worker i owns the
    // fully-reduced chunk (i+1).
    for s in 0..w - 1 {
        // snapshot the chunks being sent this step (simultaneous sends)
        let payloads: Vec<(usize, usize, Vec<f32>)> = (0..w)
            .map(|i| {
                let c = (i + w - s) % w;
                let (lo, hi) = bounds[c];
                (i, c, bufs[i][lo..hi].to_vec())
            })
            .collect();
        for (i, c, payload) in payloads {
            let dst = (i + 1) % w;
            fabric.send_params(i, dst, payload.len());
            let (lo, _) = bounds[c];
            for (k, &v) in payload.iter().enumerate() {
                bufs[dst][lo + k] += v;
            }
        }
    }
    // scale the owned chunk to the mean before sharing
    for i in 0..w {
        let c = (i + 1) % w;
        let (lo, hi) = bounds[c];
        let inv = 1.0 / w as f32;
        bufs[i][lo..hi].iter_mut().for_each(|x| *x *= inv);
    }
    // Phase 2: all-gather. In step s, worker i sends chunk (i + 1 - s).
    for s in 0..w - 1 {
        let payloads: Vec<(usize, usize, Vec<f32>)> = (0..w)
            .map(|i| {
                let c = (i + 1 + w - s) % w;
                let (lo, hi) = bounds[c];
                (i, c, bufs[i][lo..hi].to_vec())
            })
            .collect();
        for (i, c, payload) in payloads {
            let dst = (i + 1) % w;
            fabric.send_params(i, dst, payload.len());
            let (lo, _) = bounds[c];
            bufs[dst][lo..lo + payload.len()].copy_from_slice(&payload);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::LinkModel;
    use crate::util::rng::Rng;

    fn naive_mean(bufs: &[Vec<f32>]) -> Vec<f32> {
        let w = bufs.len();
        let n = bufs[0].len();
        let mut m = vec![0.0f64; n];
        for b in bufs {
            for (acc, &x) in m.iter_mut().zip(b.iter()) {
                *acc += x as f64;
            }
        }
        m.iter().map(|&x| (x / w as f64) as f32).collect()
    }

    fn check_impl(imp: AllReduceImpl, w: usize, n: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let mut bufs: Vec<Vec<f32>> = (0..w)
            .map(|_| (0..n).map(|_| rng.gauss_f32()).collect())
            .collect();
        let expect = naive_mean(&bufs);
        let mut fabric = Fabric::new(w.max(2), LinkModel::default());
        imp.all_reduce_mean(&mut bufs, &mut fabric);
        for b in &bufs {
            for (a, e) in b.iter().zip(expect.iter()) {
                assert!((a - e).abs() < 1e-4, "{imp:?} w={w} n={n}: {a} vs {e}");
            }
        }
    }

    #[test]
    fn all_impls_compute_mean() {
        for imp in [AllReduceImpl::Central, AllReduceImpl::Tree, AllReduceImpl::Ring] {
            for &w in &[2usize, 3, 4, 5, 8] {
                for &n in &[1usize, 7, 64, 130] {
                    check_impl(imp, w, n, (w * 1000 + n) as u64);
                }
            }
        }
    }

    #[test]
    fn single_worker_noop() {
        let mut bufs = vec![vec![1.0f32, 2.0]];
        let mut fabric = Fabric::new(2, LinkModel::default());
        AllReduceImpl::Ring.all_reduce_mean(&mut bufs, &mut fabric);
        assert_eq!(bufs[0], vec![1.0, 2.0]);
        assert_eq!(fabric.report().total_bytes, 0);
    }

    #[test]
    fn ring_traffic_is_bandwidth_optimal() {
        // per-worker sent bytes == 2 * (w-1)/w * n * 4 (up to chunk rounding)
        let (w, n) = (4usize, 1000usize);
        let mut rng = Rng::new(1);
        let mut bufs: Vec<Vec<f32>> = (0..w)
            .map(|_| (0..n).map(|_| rng.gauss_f32()).collect())
            .collect();
        let mut fabric = Fabric::new(w, LinkModel::default());
        AllReduceImpl::Ring.all_reduce_mean(&mut bufs, &mut fabric);
        let expect_total = 2 * (w - 1) * n * 4; // sum over workers
        assert_eq!(fabric.report().total_bytes, expect_total as u64);
        for i in 0..w {
            let sent = fabric.report().per_worker_sent[&i];
            let ideal = (2.0 * (w as f64 - 1.0) / w as f64 * n as f64 * 4.0) as i64;
            assert!((sent as i64 - ideal).abs() <= 2 * 4 * w as i64, "rank {i}: {sent} vs {ideal}");
        }
    }

    #[test]
    fn central_root_is_bottleneck() {
        let (w, n) = (8usize, 256usize);
        let mut rng = Rng::new(2);
        let mut bufs: Vec<Vec<f32>> = (0..w)
            .map(|_| (0..n).map(|_| rng.gauss_f32()).collect())
            .collect();
        let mut fabric = Fabric::new(w, LinkModel::default());
        AllReduceImpl::Central.all_reduce_mean(&mut bufs, &mut fabric);
        let root_sent = fabric.report().per_worker_sent[&0];
        let other_sent = fabric.report().per_worker_sent[&1];
        assert_eq!(root_sent, (n * 4 * (w - 1)) as u64);
        assert_eq!(other_sent, (n * 4) as u64);
    }

    #[test]
    fn chunking_covers_everything() {
        for n in [1usize, 5, 16, 17] {
            for w in [1usize, 2, 3, 5, 8] {
                let b = chunk_bounds(n, w);
                assert_eq!(b.len(), w);
                assert_eq!(b[0].0, 0);
                assert_eq!(b[w - 1].1, n);
                for win in b.windows(2) {
                    assert_eq!(win[0].1, win[1].0);
                }
            }
        }
    }
}
