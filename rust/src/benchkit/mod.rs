//! A minimal criterion-like benchmarking harness (no `criterion` in the
//! vendored set).  Used by the `[[bench]] harness = false` targets under
//! `rust/benches/`.
//!
//! Methodology: warmup until timing stabilizes (or warmup budget spent),
//! then measure `samples` batches of `iters` runs; report median, mean,
//! MAD and min.  Wall-clock only — good enough to rank implementations
//! and detect >5% regressions, which is all the perf loop needs.

use std::time::Instant;

#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    /// per-iteration seconds
    pub median_s: f64,
    pub mean_s: f64,
    pub min_s: f64,
    /// median absolute deviation (robust spread)
    pub mad_s: f64,
    pub iters_per_sample: u64,
    pub samples: usize,
}

impl Stats {
    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.median_s
    }

    pub fn print(&self) {
        println!(
            "{:<44} {:>12} median {:>12} mean {:>12} min  (±{} mad, {}x{})",
            self.name,
            fmt_time(self.median_s),
            fmt_time(self.mean_s),
            fmt_time(self.min_s),
            fmt_time(self.mad_s),
            self.samples,
            self.iters_per_sample,
        );
    }

    pub fn print_with_throughput(&self, units_per_iter: f64, unit: &str) {
        println!(
            "{:<44} {:>12} median   {:>14.3} {unit}/s",
            self.name,
            fmt_time(self.median_s),
            self.throughput(units_per_iter)
        );
    }
}

pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Benchmark `f`, auto-choosing the per-sample iteration count so each
/// sample takes ≥ `min_sample_s`.
pub fn bench(name: &str, mut f: impl FnMut()) -> Stats {
    bench_cfg(name, 12, 0.02, 1.0, &mut f)
}

/// Lighter-weight variant for expensive bodies (e.g. whole train epochs).
pub fn bench_heavy(name: &str, samples: usize, mut f: impl FnMut()) -> Stats {
    bench_cfg(name, samples.max(3), 0.0, 0.0, &mut f)
}

fn bench_cfg(
    name: &str,
    samples: usize,
    min_sample_s: f64,
    warmup_budget_s: f64,
    f: &mut dyn FnMut(),
) -> Stats {
    // warmup + calibration
    let mut iters: u64 = 1;
    let warm_start = Instant::now();
    loop {
        let t = Instant::now();
        for _ in 0..iters {
            f();
        }
        let dt = t.elapsed().as_secs_f64();
        if dt >= min_sample_s || warm_start.elapsed().as_secs_f64() > warmup_budget_s {
            if dt < min_sample_s && dt > 0.0 {
                iters = ((iters as f64) * (min_sample_s / dt).max(1.0)).ceil() as u64;
            }
            break;
        }
        iters = iters.saturating_mul(2);
    }

    let mut per_iter: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..iters {
            f();
        }
        per_iter.push(t.elapsed().as_secs_f64() / iters as f64);
    }
    per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = per_iter[per_iter.len() / 2];
    let mean = per_iter.iter().sum::<f64>() / per_iter.len() as f64;
    let min = per_iter[0];
    let mut devs: Vec<f64> = per_iter.iter().map(|x| (x - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = devs[devs.len() / 2];
    Stats {
        name: name.to_string(),
        median_s: median,
        mean_s: mean,
        min_s: min,
        mad_s: mad,
        iters_per_sample: iters,
        samples: per_iter.len(),
    }
}

/// Comparison table helper: prints rows with a ratio column vs the first.
pub fn print_comparison(title: &str, stats: &[Stats]) {
    println!("\n== {title} ==");
    if stats.is_empty() {
        return;
    }
    let base = stats[0].median_s;
    for s in stats {
        println!(
            "{:<44} {:>12}   x{:.2}",
            s.name,
            fmt_time(s.median_s),
            s.median_s / base
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let s = bench_cfg("spin", 5, 0.001, 0.05, &mut || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(s.median_s > 0.0);
        assert!(s.min_s <= s.median_s);
        assert!(s.samples == 5);
    }

    #[test]
    fn ranks_slow_vs_fast() {
        let fast = bench_cfg("fast", 5, 0.001, 0.05, &mut || {
            std::hint::black_box((0..10u64).sum::<u64>());
        });
        let slow = bench_cfg("slow", 5, 0.001, 0.05, &mut || {
            std::hint::black_box((0..10_000u64).product::<u64>());
        });
        assert!(slow.median_s > fast.median_s);
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2e-9).contains("ns"));
        assert!(fmt_time(2e-6).contains("µs"));
        assert!(fmt_time(2e-3).contains("ms"));
        assert!(fmt_time(2.0).contains(" s"));
    }
}
