//! Command-line interface for the `repro` binary (hand-rolled; the
//! vendored dependency set has no `clap`).
//!
//! ```text
//! repro presets                         list every paper experiment label
//! repro table <4.1|4.2|4.3|a.1> [...]   regenerate a paper table
//! repro figure <4.1|4.2|4.3|4.4> [...]  regenerate a figure's CSV series
//! repro train [--preset L|--config F]   run one experiment
//! repro comm-cost                       traffic accounting (AR vs gossip)
//! repro async-sim                       controlled-asynchrony study (time-only)
//! repro async-train                     event-driven async training under stragglers
//! repro churn-train                     elastic-membership study (crash/rejoin schedules)
//! repro trace-dump                      traced smoke run -> validated Chrome trace JSON
//! repro inspect                         artifact manifest summary
//!
//! common flags:
//!   --scale N        shrink dataset by N (default 10; 1 = paper size)
//!   --epochs E       override epoch count (default 5; paper: 100/50)
//!   --full           paper scale (= --scale 1, paper epochs)
//!   --synthetic      use the closed-form engine instead of HLO (fast)
//!   --out DIR        write CSV/JSON outputs here (default results/)
//!   --artifacts DIR  artifact directory (default artifacts/)
//!   --seed S         experiment seed
//!   --codec C        wire codec for async gossip payloads
//!                    (identity | q8[:<chunk>] | topk:<frac>)
//!   --shards N       event-queue shards for the async runtime (default 1;
//!                    trajectory is bit-identical for every N)
//!   --coalesce       pack same-destination gossip payloads into one frame
//!   --trace SPEC     flight-recorder tracing
//!                    (off | on[,ring:<n>][,wall][,dump:<path>])
//!   --verbose        per-epoch progress on stderr
//! ```

pub mod paper_ref;

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::config::{DatasetKind, EngineKind, ExperimentConfig};
use crate::coordinator::{run_experiment_verbose, RunReport};
use crate::manifest::Manifest;
use crate::metrics::write_curves_csv;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                // boolean flags take no value; everything else takes one
                let is_bool =
                    matches!(
                        name,
                        "full" | "synthetic" | "verbose" | "help" | "parallel" | "coalesce"
                            | "rejoin"
                    );
                if is_bool {
                    out.flags.insert(name.to_string(), "true".into());
                } else {
                    i += 1;
                    let v = argv
                        .get(i)
                        .ok_or_else(|| anyhow!("flag --{name} needs a value"))?;
                    out.flags.insert(name.to_string(), v.clone());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn flag_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name} {v}: {e}")),
        }
    }
}

/// Apply the common scale/engine/seed flags to a preset config.
pub fn apply_common_flags(mut cfg: ExperimentConfig, args: &Args) -> Result<ExperimentConfig> {
    let full = args.has("full");
    let scale: usize = args.flag_parse("scale", if full { 1 } else { 10 })?;
    let default_epochs = if full { cfg.epochs } else { 5 };
    let epochs: usize = args.flag_parse("epochs", default_epochs)?;
    cfg = cfg.scaled(scale.max(1), epochs);
    if args.has("synthetic") {
        cfg.engine = EngineKind::Synthetic { dim: 64 };
        cfg.dataset = DatasetKind::SyntheticVectors { dim: 16 };
        // synthetic engine is shape-free; keep batch arithmetic intact
    }
    if let Some(d) = args.flag("artifacts") {
        cfg.artifact_dir = PathBuf::from(d);
    }
    if let Some(c) = args.flag("codec") {
        cfg.codec = crate::comm::codec::CodecKind::parse(c)?;
    }
    if let Some(c) = args.flag("churn") {
        cfg.churn = crate::membership::ChurnSpec::parse(c)?;
    }
    if let Some(c) = args.flag("faults") {
        cfg.faults = crate::membership::FaultSpec::parse(c)?;
    }
    if let Some(c) = args.flag("fd") {
        cfg.fd = crate::membership::FdSpec::parse(c)?;
    }
    cfg.shards = args.flag_parse("shards", cfg.shards)?;
    if args.has("coalesce") {
        cfg.coalesce = true;
    }
    if let Some(t) = args.flag("transport") {
        cfg.transport = crate::comm::transport::TransportKind::parse(t)?;
    }
    if let Some(t) = args.flag("trace") {
        cfg.trace = crate::trace::TraceSpec::parse(t)?;
    }
    cfg.seed = args.flag_parse("seed", cfg.seed)?;
    Ok(cfg)
}

fn out_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.flag("out").unwrap_or("results"))
}

// ---------------------------------------------------------------------------
// subcommands
// ---------------------------------------------------------------------------

pub fn main_with_args(argv: &[String]) -> Result<i32> {
    let args = Args::parse(argv)?;
    if args.positional.is_empty() || args.has("help") {
        print_usage();
        return Ok(0);
    }
    match args.positional[0].as_str() {
        "presets" => cmd_presets(),
        "table" => cmd_table(&args),
        "figure" => cmd_figure(&args),
        "train" => cmd_train(&args),
        "comm-cost" => cmd_comm_cost(&args),
        "async-sim" => cmd_async_sim(&args),
        "async-train" => cmd_async_train(&args),
        "net-train" => cmd_net_train(&args),
        "churn-train" => cmd_churn_train(&args),
        "trace-dump" => cmd_trace_dump(&args),
        "inspect" => cmd_inspect(&args),
        other => bail!("unknown subcommand {other:?} (try `repro --help`)"),
    }
}

fn print_usage() {
    println!("{}", include_str!("usage.txt"));
}

fn cmd_presets() -> Result<i32> {
    println!("{:<22} {:>3} {:<22} {:<16} {}", "label", "W", "method", "schedule", "model");
    for c in ExperimentConfig::all_presets() {
        let model = match &c.engine {
            EngineKind::Hlo { model } => model.clone(),
            EngineKind::Synthetic { .. } => "synthetic".into(),
        };
        println!(
            "{:<22} {:>3} {:<22} {:<16} {}",
            c.label,
            c.workers,
            format!("{:?}", c.method),
            format!("{:?}", c.schedule),
            model
        );
    }
    Ok(0)
}

/// Which preset labels make up each table.
pub fn table_labels(table: &str) -> Result<Vec<&'static str>> {
    Ok(match table {
        "4.1" => paper_ref::TABLE_4_1.iter().map(|r| r.0).collect(),
        "4.2" => paper_ref::TABLE_4_2.iter().map(|r| r.0).collect(),
        "4.3" => paper_ref::TABLE_4_3.iter().map(|r| r.0).collect(),
        "a.1" | "A.1" => paper_ref::TABLE_A_1.iter().map(|r| r.0).collect(),
        other => bail!("unknown table {other:?} (4.1 | 4.2 | 4.3 | a.1)"),
    })
}

fn reference_table(table: &str) -> &'static [paper_ref::Row] {
    match table {
        "4.1" => paper_ref::TABLE_4_1,
        "4.2" => paper_ref::TABLE_4_2,
        "4.3" => paper_ref::TABLE_4_3,
        _ => paper_ref::TABLE_A_1,
    }
}

fn cmd_table(args: &Args) -> Result<i32> {
    let table = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("usage: repro table <4.1|4.2|4.3|a.1>"))?
        .clone();
    let labels = table_labels(&table)?;
    let only: Option<Vec<&str>> = args.flag("only").map(|s| s.split(',').collect());
    let verbose = args.has("verbose");

    println!("# Table {table} — paper vs measured (synthetic-data substitution; see DESIGN.md §4)");
    println!(
        "{:<20} {:>12} {:>12} {:>12} {:>12} {:>14} {:>10}",
        "label", "paper-rank0", "meas-rank0", "paper-agg", "meas-agg", "comm-MB", "wall-s"
    );
    let mut curves = Vec::new();
    let mut reports: Vec<RunReport> = Vec::new();
    for label in labels {
        if let Some(ref o) = only {
            if !o.contains(&label) {
                continue;
            }
        }
        let cfg = apply_common_flags(ExperimentConfig::preset(label)?, args)?;
        let report = run_experiment_verbose(&cfg, verbose)?;
        let (_, p_r0, p_agg) = paper_ref::lookup(reference_table(&table), label)
            .unwrap_or((label, f32::NAN, None));
        println!(
            "{:<20} {:>12.4} {:>12.4} {:>12} {:>12.4} {:>14.2} {:>10.1}",
            label,
            p_r0,
            report.rank0_accuracy,
            p_agg.map(|a| format!("{a:.4}")).unwrap_or_else(|| "-".into()),
            report.aggregate_accuracy,
            report.metrics.comm_bytes as f64 / 1e6,
            report.metrics.wall_train_s,
        );
        curves.push(report.metrics.curve.clone());
        reports.push(report);
    }
    let dir = out_dir(args).join(format!("table_{}", table.replace('.', "_")));
    let paths = write_curves_csv(&dir, &curves)?;
    write_summary_json(&dir, &reports)?;
    println!("# wrote {} curve CSVs + summary.json under {}", paths.len(), dir.display());
    Ok(0)
}

pub fn write_summary_json(dir: &std::path::Path, reports: &[RunReport]) -> Result<()> {
    use crate::manifest::json::{Json, JsonObj};
    std::fs::create_dir_all(dir)?;
    let mut o = JsonObj::new();
    for r in reports {
        o.insert(r.label.clone(), r.metrics.summary_json());
    }
    std::fs::write(dir.join("summary.json"), crate::manifest::json::write(&Json::Obj(o)))?;
    Ok(())
}

/// Figure → which preset labels produce its series.
pub fn figure_labels(fig: &str) -> Result<Vec<String>> {
    Ok(match fig {
        // single-worker baseline, 4 seeds (harness varies seed)
        "4.1" => vec!["SGD-1".into()],
        // comparable-configs panel
        "4.2" => vec![
            "AR-4".into(),
            "NC-4".into(),
            "EG-4-0.125".into(),
            "GS-4-0.125".into(),
            "EG-4-0.031".into(),
            "GS-4-0.031".into(),
        ],
        // EG vs GS grid over (W, p)
        "4.3" => paper_ref::TABLE_4_1
            .iter()
            .map(|r| r.0.to_string())
            .filter(|l| l.starts_with("EG") || l.starts_with("GS"))
            .collect(),
        // alpha sweep
        "4.4" => paper_ref::TABLE_4_2.iter().map(|r| r.0.to_string()).collect(),
        other => bail!("unknown figure {other:?} (4.1 | 4.2 | 4.3 | 4.4)"),
    })
}

fn cmd_figure(args: &Args) -> Result<i32> {
    let fig = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("usage: repro figure <4.1|4.2|4.3|4.4>"))?
        .clone();
    let verbose = args.has("verbose");
    let labels = figure_labels(&fig)?;
    let mut curves = Vec::new();
    if fig == "4.1" {
        // four random initializations, as in the paper
        for seed in 0..4u64 {
            let mut cfg = apply_common_flags(ExperimentConfig::preset("SGD-1")?, args)?;
            cfg.seed = seed;
            cfg.label = format!("SGD-1-seed{seed}");
            let report = run_experiment_verbose(&cfg, verbose)?;
            println!(
                "SGD-1 seed {seed}: test acc {:.4} (paper band {:.4}-{:.4})",
                report.rank0_accuracy,
                paper_ref::BASELINE_RANGE.0,
                paper_ref::BASELINE_RANGE.1
            );
            curves.push(report.metrics.curve);
        }
    } else {
        for label in labels {
            let cfg = apply_common_flags(ExperimentConfig::preset(&label)?, args)?;
            let report = run_experiment_verbose(&cfg, verbose)?;
            println!(
                "{label}: final val acc mean {:.4} (rank0 test {:.4})",
                report.metrics.curve.last().map(|p| p.acc_mean()).unwrap_or(0.0),
                report.rank0_accuracy
            );
            curves.push(report.metrics.curve);
        }
    }
    let dir = out_dir(args).join(format!("figure_{}", fig.replace('.', "_")));
    let paths = write_curves_csv(&dir, &curves)?;
    println!("# wrote {} series CSVs under {} (epoch,mean,min,max columns)", paths.len(), dir.display());
    Ok(0)
}

fn cmd_train(args: &Args) -> Result<i32> {
    let cfg = if let Some(path) = args.flag("config") {
        let text = std::fs::read_to_string(path)?;
        ExperimentConfig::from_toml(&text)?
    } else if let Some(preset) = args.flag("preset") {
        ExperimentConfig::preset(preset)?
    } else {
        bail!("usage: repro train (--preset LABEL | --config FILE) [flags]");
    };
    let cfg = apply_common_flags(cfg, args)?;
    let parallel = args.has("parallel");
    eprintln!(
        "[train] {} | method {:?} | {} workers | schedule {:?} | {} epochs x {} steps",
        cfg.label,
        cfg.method,
        cfg.workers,
        cfg.schedule,
        cfg.epochs,
        cfg.steps_per_epoch()
    );
    let report = if parallel {
        // threaded runtime: one engine per worker thread
        match &cfg.engine {
            EngineKind::Hlo { model } => {
                let spec = crate::runtime::HloEngineSpec {
                    artifact_dir: cfg.artifact_dir.clone(),
                    model: model.clone(),
                    train_batch: cfg.per_worker_batch(),
                    workers: 1,
                };
                crate::coordinator::parallel::run_parallel(&cfg, &spec)?
            }
            EngineKind::Synthetic { dim } => {
                let spec = crate::runtime::SyntheticSpec {
                    n: *dim,
                    classes: 10,
                    train_b: cfg.per_worker_batch(),
                    eval_b: 32,
                    seed: cfg.seed ^ 0x5EED,
                };
                crate::coordinator::parallel::run_parallel(&cfg, &spec)?
            }
        }
    } else {
        run_experiment_verbose(&cfg, true)?
    };
    println!("rank0 test accuracy      {:.4}", report.rank0_accuracy);
    println!("aggregate test accuracy  {:.4}", report.aggregate_accuracy);
    println!("total steps              {}", report.metrics.total_steps);
    println!("comm bytes               {}", report.metrics.comm_bytes);
    println!("wire bytes (encoded)     {}", report.metrics.wire_bytes);
    println!("comm rounds              {}", report.metrics.comm_rounds);
    println!("simulated comm seconds   {:.4}", report.metrics.simulated_comm_s);
    println!("train wall seconds       {:.2}", report.metrics.wall_train_s);
    let dir = out_dir(args).join("train");
    write_curves_csv(&dir, &[report.metrics.curve.clone()])?;
    write_summary_json(&dir, &[report])?;
    Ok(0)
}

/// Communication-cost accounting: the paper's headline claim that gossip
/// needs a fraction of All-reduce's traffic, quantified per method.
fn cmd_comm_cost(args: &Args) -> Result<i32> {
    use crate::algos::Method;
    use crate::config::CommSchedule;
    let n: usize = args.flag_parse("flat", 2_913_290usize)?; // paper MLP size
    let steps: u64 = args.flag_parse("steps", 400u64)?; // one paper epoch
    println!("# bytes per worker-step, model flat size {n} f32 ({:.1} MB), {steps} steps", n as f64 * 4.0 / 1e6);
    println!(
        "{:<28} {:>14} {:>16} {:>12}",
        "method", "total MB", "MB/worker/step", "vs AR-ring"
    );
    let mut base = None;
    for (label, method, sched) in [
        ("allreduce-ring (AR)", Method::AllReduce { imp: crate::collective::AllReduceImpl::Ring }, CommSchedule::EveryStep),
        ("allreduce-central", Method::AllReduce { imp: crate::collective::AllReduceImpl::Central }, CommSchedule::EveryStep),
        ("elastic-gossip p=0.125", Method::ElasticGossip { alpha: 0.5 }, CommSchedule::Probability(0.125)),
        ("elastic-gossip p=0.031", Method::ElasticGossip { alpha: 0.5 }, CommSchedule::Probability(0.03125)),
        ("gossip-pull p=0.125", Method::GossipingSgdPull, CommSchedule::Probability(0.125)),
        ("gossip-pull p=0.031", Method::GossipingSgdPull, CommSchedule::Probability(0.03125)),
        ("easgd tau=10", Method::Easgd { alpha: 0.125 }, CommSchedule::Period(10)),
    ] {
        let mut cfg = crate::coordinator::synthetic_cfg(method, 4, n);
        cfg.schedule = sched;
        cfg.epochs = 1;
        cfg.n_train = (steps as usize) * cfg.effective_batch;
        let report = crate::coordinator::run_experiment(&cfg)?;
        let mb = report.metrics.comm_bytes as f64 / 1e6;
        let per = mb / (4.0 * steps as f64);
        let ratio = match base {
            None => {
                base = Some(mb);
                1.0
            }
            Some(b) => mb / b,
        };
        println!("{label:<28} {mb:>14.2} {per:>16.4} {ratio:>12.4}");
    }
    Ok(0)
}

fn cmd_async_sim(args: &Args) -> Result<i32> {
    use crate::comm::LinkModel;
    use crate::sim::{simulate_asynchronous, simulate_synchronous, WorkerSpeed};
    let w: usize = args.flag_parse("workers", 8usize)?;
    let steps: u64 = args.flag_parse("steps", 2000u64)?;
    let slow: f64 = args.flag_parse("straggler", 3.0f64)?;
    println!("# controlled-asynchrony study: {w} workers, {steps} steps, straggler x{slow}");
    println!(
        "{:<26} {:>12} {:>12} {:>14} {:>12}",
        "scenario", "virtual-s", "waste-frac", "async-speedup", "staleness"
    );
    for (name, factor) in [("homogeneous", 1.0f64), ("one straggler", slow)] {
        let mut speeds: Vec<WorkerSpeed> = (0..w).map(|_| WorkerSpeed::uniform(0.1)).collect();
        speeds[w - 1].slow_factor = factor;
        let sync = simulate_synchronous(&speeds, steps, 0, LinkModel::default(), 7);
        let asy = simulate_asynchronous(&speeds, steps, 0.125, 7);
        println!(
            "{:<26} {:>12.1} {:>12.3} {:>14.2} {:>12.2}",
            format!("{name} (sync)"),
            sync.total_s,
            sync.waste_fraction(),
            sync.speedup_if_async(),
            0.0
        );
        println!(
            "{:<26} {:>12.1} {:>12.3} {:>14} {:>12.2}",
            format!("{name} (async)"),
            asy.total_s,
            asy.waste_fraction(),
            "-",
            asy.mean_async_staleness
        );
    }
    Ok(0)
}

/// Real training on the event-driven asynchronous runtime: accuracy,
/// loss, *measured* staleness and bytes-on-wire under a straggler, next
/// to the synchronous reference.  `--codec q8` / `--codec topk:0.01`
/// makes this the bandwidth-constrained straggler study.
fn cmd_async_train(args: &Args) -> Result<i32> {
    use crate::algos::Method;
    use crate::comm::codec::CodecKind;
    use crate::coordinator::run_experiment;
    use crate::runtime_async::{run_async, study_setup, AsyncSimCfg};

    let w: usize = args.flag_parse("workers", 8usize)?;
    let slow: f64 = args.flag_parse("straggler", 4.0f64)?;
    let prob: f64 = args.flag_parse("prob", 0.125f64)?;
    let method = Method::parse(args.flag("method").unwrap_or("elastic-gossip:0.5"))?;
    if let Some(list) = args.flag("topologies") {
        return topology_sweep(args, list, w, slow, prob);
    }
    let (mut cfg, spec) = study_setup(
        method,
        w,
        prob,
        args.flag_parse("epochs", 6usize)?,
        args.flag_parse("seed", 7u64)?,
    );
    cfg.codec = CodecKind::parse(args.flag("codec").unwrap_or("identity"))?;
    if let Some(c) = args.flag("churn") {
        cfg.churn = crate::membership::ChurnSpec::parse(c)?;
    }
    if let Some(c) = args.flag("faults") {
        cfg.faults = crate::membership::FaultSpec::parse(c)?;
    }
    if let Some(c) = args.flag("fd") {
        cfg.fd = crate::membership::FdSpec::parse(c)?;
    }
    cfg.shards = args.flag_parse("shards", cfg.shards)?;
    if args.has("coalesce") {
        cfg.coalesce = true;
    }
    if let Some(t) = args.flag("transport") {
        cfg.transport = crate::comm::transport::TransportKind::parse(t)?;
    }
    if let Some(t) = args.flag("trace") {
        cfg.trace = crate::trace::TraceSpec::parse(t)?;
    }
    if cfg.transport == crate::comm::transport::TransportKind::LoopbackUdp
        && !crate::comm::transport::probe_loopback()
    {
        println!("async-train: transport loopback-udp unavailable (socket bind forbidden); falling back to inproc");
        cfg.transport = crate::comm::transport::TransportKind::InProc;
    }
    // the synchronous reference always ships raw snapshots on a fixed
    // roster over perfect links
    let sync_cfg = ExperimentConfig {
        codec: CodecKind::Identity,
        churn: crate::membership::ChurnSpec::none(),
        faults: crate::membership::FaultSpec::none(),
        fd: crate::membership::FdSpec::none(),
        ..cfg.clone()
    };
    let sync = run_experiment(&sync_cfg)?;
    println!(
        "# sync reference: rank0 {:.4} aggregate {:.4} | async codec {}",
        sync.rank0_accuracy,
        sync.aggregate_accuracy,
        cfg.codec.label()
    );
    println!(
        "{:<22} {:>8} {:>8} {:>10} {:>9} {:>9} {:>9} {:>10} {:>10} {:>11} {:>9}",
        "scenario", "rank0", "agg", "stale-avg", "p50", "p95", "p99", "stale-max", "util", "wire-MB", "vs-raw"
    );
    for (name, factor) in [("homogeneous", 1.0f64), ("straggler", slow)] {
        let sim = AsyncSimCfg::straggler(w, 0.05, 0.1, factor);
        let asy = run_async(&cfg, &spec, &sim)?;
        let m = &asy.report.metrics;
        let reduction = if m.wire_bytes > 0 {
            m.comm_bytes as f64 / m.wire_bytes as f64
        } else {
            1.0
        };
        println!(
            "{:<22} {:>8.4} {:>8.4} {:>10.2} {:>9} {:>9} {:>9} {:>10} {:>10.3} {:>11.3} {:>8.2}x",
            name,
            asy.report.rank0_accuracy,
            asy.report.aggregate_accuracy,
            asy.staleness.mean(),
            asy.staleness.p50(),
            asy.staleness.p95(),
            asy.staleness.p99(),
            asy.staleness.max(),
            asy.mean_self_utilization(),
            m.wire_bytes as f64 / 1e6,
            reduction,
        );
    }
    Ok(0)
}

/// `repro net-train` — free-running multi-process training over real
/// UDP sockets (the `udp` transport).  The parent spawns one worker
/// process per rank; ranks rendezvous through a handshake directory,
/// checkpoint at epoch boundaries, and can be SIGKILLed + restarted with
/// `--rejoin` (donor bootstrap + incarnation refutation, PR 5/6
/// semantics on a real wire).  `--net-worker <rank>` is the internal
/// re-entry flag the parent uses to spawn itself.
fn cmd_net_train(args: &Args) -> Result<i32> {
    use crate::algos::Method;
    use crate::comm::codec::CodecKind;
    use crate::comm::transport::probe_loopback;
    use crate::runtime_async::net::{
        print_fleet_table, run_net_parent, run_net_worker, NetTrainCfg,
    };

    let nc = NetTrainCfg {
        method: Method::parse(args.flag("method").unwrap_or("elastic-gossip:0.5"))?,
        workers: args.flag_parse("workers", 3usize)?,
        epochs: args.flag_parse("epochs", 4usize)?,
        prob: args.flag_parse("prob", 0.25f64)?,
        seed: args.flag_parse("seed", 7u64)?,
        codec: CodecKind::parse(args.flag("codec").unwrap_or("identity"))?,
        pace_ms: args.flag_parse("pace-ms", 20u64)?,
        straggler: args.flag_parse("straggler", 1.5f64)?,
        rendezvous: PathBuf::from(
            args.flag("rendezvous").unwrap_or("results/net_rendezvous"),
        ),
        out: PathBuf::from(args.flag("out").unwrap_or("results/net_train")),
        linger_ms: args.flag_parse("linger-ms", 1500u64)?,
        trace: match args.flag("trace") {
            Some(t) => crate::trace::TraceSpec::parse(t)?,
            None => crate::trace::TraceSpec::off(),
        },
    };
    if let Some(r) = args.flag("net-worker") {
        let rank: usize = r.parse().map_err(|_| anyhow!("bad --net-worker rank {r:?}"))?;
        run_net_worker(&nc, rank, args.has("rejoin"))?;
        return Ok(0);
    }
    if !probe_loopback() {
        println!("net-train skipped: no network (loopback socket bind forbidden)");
        return Ok(0);
    }
    let exe = std::env::current_exe().context("resolving the repro binary path")?;
    let ranks = run_net_parent(&nc, &exe)?;
    print_fleet_table(&ranks);
    println!("# per-rank summaries + summary.json in {}", nc.out.display());
    Ok(0)
}

/// `repro trace-dump` — run a small traced async study, validate the
/// emitted flight-recorder JSON against the Chrome trace-event schema,
/// and write it where a browser (Perfetto / `chrome://tracing`) can
/// load it.  Doubles as the observability smoke test in CI.
fn cmd_trace_dump(args: &Args) -> Result<i32> {
    use crate::algos::Method;
    use crate::runtime_async::{run_async, study_setup, AsyncSimCfg};

    let w: usize = args.flag_parse("workers", 4usize)?;
    let (mut cfg, spec) = study_setup(
        Method::parse(args.flag("method").unwrap_or("elastic-gossip:0.5"))?,
        w,
        args.flag_parse("prob", 0.25f64)?,
        args.flag_parse("epochs", 2usize)?,
        args.flag_parse("seed", 7u64)?,
    );
    cfg.trace = crate::trace::TraceSpec::parse(args.flag("trace").unwrap_or("on"))?;
    anyhow::ensure!(!cfg.trace.is_off(), "trace-dump needs an `on` trace spec");
    if let Some(c) = args.flag("codec") {
        cfg.codec = crate::comm::codec::CodecKind::parse(c)?;
    }
    cfg.shards = args.flag_parse("shards", cfg.shards)?;
    let sim = AsyncSimCfg::straggler(w, 0.05, 0.1, args.flag_parse("straggler", 3.0f64)?);
    let asy = run_async(&cfg, &spec, &sim)?;
    let json = asy
        .trace_json
        .context("traced run returned no trace JSON")?;
    let n = crate::trace::validate_chrome_trace(&json)?;
    let dir = out_dir(args).join("trace");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{}.json", cfg.label));
    std::fs::write(&path, &json).with_context(|| format!("writing {path:?}"))?;
    println!("# {n} trace events, valid Chrome trace-event JSON");
    println!("# wrote {} (load in Perfetto: https://ui.perfetto.dev)", path.display());
    Ok(0)
}

/// Topology-aware async study (the ROADMAP open item): sweep
/// `--topologies ring,torus:4,randreg:3:7,...` in one invocation and
/// emit a staleness-vs-topology summary table (stdout + JSON).
fn topology_sweep(args: &Args, list: &str, w: usize, slow: f64, prob: f64) -> Result<i32> {
    use crate::algos::Method;
    use crate::manifest::json::{Json, JsonObj};
    use crate::runtime_async::{run_async, study_setup, AsyncSimCfg};
    use crate::topology::Topology;

    let method = Method::parse(args.flag("method").unwrap_or("elastic-gossip:0.5"))?;
    let epochs: usize = args.flag_parse("epochs", 6usize)?;
    let seed: u64 = args.flag_parse("seed", 7u64)?;
    // the sweep honors the same --codec/--churn flags as a single run
    let codec = crate::comm::codec::CodecKind::parse(args.flag("codec").unwrap_or("identity"))?;
    let churn = match args.flag("churn") {
        Some(c) => crate::membership::ChurnSpec::parse(c)?,
        None => crate::membership::ChurnSpec::none(),
    };
    println!(
        "# staleness vs topology: {w} workers, straggler x{slow}, p={prob}, method {:?}",
        method
    );
    println!(
        "{:<16} {:>8} {:>8} {:>10} {:>9} {:>9} {:>10} {:>10} {:>12}",
        "topology", "rank0", "agg", "stale-avg", "p50", "p95", "stale-max", "stale-frac", "comm-MB"
    );
    let mut root = JsonObj::new();
    for t in list.split(',') {
        let topo = Topology::parse(t.trim())?;
        anyhow::ensure!(topo.is_connected(w), "topology {t:?} is disconnected at W={w}");
        let (mut cfg, spec) = study_setup(method.clone(), w, prob, epochs, seed);
        cfg.topology = topo;
        cfg.codec = codec;
        cfg.churn = churn.clone();
        cfg.shards = args.flag_parse("shards", cfg.shards)?;
        if args.has("coalesce") {
            cfg.coalesce = true;
        }
        cfg.label = format!("async-{}-{}", method.short_label(), t.trim());
        let sim = AsyncSimCfg::straggler(w, 0.05, 0.1, slow);
        let asy = run_async(&cfg, &spec, &sim)?;
        let m = &asy.report.metrics;
        println!(
            "{:<16} {:>8.4} {:>8.4} {:>10.2} {:>9} {:>9} {:>10} {:>10.3} {:>12.3}",
            t.trim(),
            asy.report.rank0_accuracy,
            asy.report.aggregate_accuracy,
            asy.staleness.mean(),
            asy.staleness.p50(),
            asy.staleness.p95(),
            asy.staleness.max(),
            asy.staleness.stale_fraction(),
            m.comm_bytes as f64 / 1e6,
        );
        let mut o = JsonObj::new();
        o.insert("rank0_test_acc", Json::Num(asy.report.rank0_accuracy as f64));
        o.insert("aggregate_test_acc", Json::Num(asy.report.aggregate_accuracy as f64));
        o.insert("staleness", asy.staleness.to_json());
        o.insert("comm_bytes", Json::Num(m.comm_bytes as f64));
        o.insert("wire_bytes", Json::Num(m.wire_bytes as f64));
        o.insert("virtual_s", Json::Num(asy.virtual_s));
        root.insert(t.trim(), Json::Obj(o));
    }
    let dir = out_dir(args).join("async_topo");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("summary.json");
    std::fs::write(&path, crate::manifest::json::write(&Json::Obj(root)))?;
    println!("# wrote staleness-vs-topology summary to {}", path.display());
    Ok(0)
}

/// Elastic-membership study: run the paper-style experiment under a
/// crash/rejoin schedule across gossip methods and wire codecs, and
/// report survivor accuracy, dropped traffic and push-sum mass.
fn cmd_churn_train(args: &Args) -> Result<i32> {
    use crate::algos::Method;
    use crate::comm::codec::CodecKind;
    use crate::manifest::json::{Json, JsonObj};
    use crate::membership::ChurnSpec;
    use crate::runtime_async::{run_async, study_setup, AsyncSimCfg};

    let w: usize = args.flag_parse("workers", 8usize)?;
    let slow: f64 = args.flag_parse("straggler", 3.0f64)?;
    let prob: f64 = args.flag_parse("prob", 0.125f64)?;
    let epochs: usize = args.flag_parse("epochs", 8usize)?;
    let seed: u64 = args.flag_parse("seed", 7u64)?;
    // default: the acceptance schedule — two crashes mid-run, one rejoin
    let spec_str = args
        .flag("churn")
        .unwrap_or(crate::membership::STANDARD_CHURN);
    let churn = ChurnSpec::parse(spec_str)?;
    anyhow::ensure!(!churn.is_empty(), "churn-train needs a non-empty --churn schedule");
    // optional robustness plane: lossy links and/or gossip-native detection
    let faults = match args.flag("faults") {
        Some(c) => crate::membership::FaultSpec::parse(c)?,
        None => crate::membership::FaultSpec::none(),
    };
    let fd = match args.flag("fd") {
        Some(c) => crate::membership::FdSpec::parse(c)?,
        None => crate::membership::FdSpec::none(),
    };

    let methods: Vec<Method> = match args.flag("method") {
        Some(m) => vec![Method::parse(m)?],
        None => vec![
            Method::ElasticGossip { alpha: 0.5 },
            Method::GossipingSgdPull,
            Method::GossipingSgdPush,
            Method::GoSgd,
        ],
    };
    let codecs: Vec<CodecKind> = match args.flag("codec") {
        Some(c) => c.split(',').map(CodecKind::parse).collect::<Result<_>>()?,
        None => vec![
            CodecKind::Identity,
            CodecKind::Q8 { chunk: 4096 },
            CodecKind::TopK { frac: 0.25 },
        ],
    };

    println!("# elastic membership study: {w} workers, churn `{}`", churn.label());
    println!(
        "{:<10} {:<10} {:>6} {:>8} {:>8} {:>10} {:>9} {:>11} {:>9} {:>8}",
        "method", "codec", "alive", "rank0", "agg", "loss", "dropped", "dropped-kB", "rollback", "mass"
    );
    let mut root = JsonObj::new();
    for method in &methods {
        for codec in &codecs {
            let (mut cfg, spec) = study_setup(method.clone(), w, prob, epochs, seed);
            cfg.codec = *codec;
            cfg.churn = churn.clone();
            cfg.faults = faults.clone();
            cfg.fd = fd.clone();
            cfg.shards = args.flag_parse("shards", cfg.shards)?;
            if args.has("coalesce") {
                cfg.coalesce = true;
            }
            cfg.label = format!("churn-{}-{}", method.short_label(), codec.label());
            let sim = AsyncSimCfg::straggler(w, 0.05, 0.1, slow);
            let asy = run_async(&cfg, &spec, &sim)?;
            let m = &asy.report.metrics;
            let mass = asy.push_sum_mass;
            println!(
                "{:<10} {:<10} {:>6} {:>8.4} {:>8.4} {:>10.4} {:>9} {:>11.2} {:>9} {:>8}",
                method.short_label(),
                codec.label(),
                asy.membership.final_alive.len(),
                asy.report.rank0_accuracy,
                asy.report.aggregate_accuracy,
                m.curve.points.last().map(|p| p.train_loss).unwrap_or(f32::NAN),
                m.dropped_messages,
                m.dropped_bytes as f64 / 1e3,
                asy.membership.rolled_back_msgs,
                mass.map(|x| format!("{x:.9}")).unwrap_or_else(|| "-".into()),
            );
            let mut o = JsonObj::new();
            o.insert("rank0_test_acc", Json::Num(asy.report.rank0_accuracy as f64));
            o.insert("aggregate_test_acc", Json::Num(asy.report.aggregate_accuracy as f64));
            o.insert("dropped_messages", Json::Num(m.dropped_messages as f64));
            o.insert("dropped_bytes", Json::Num(m.dropped_bytes as f64));
            if let Some(x) = mass {
                o.insert("push_sum_mass", Json::Num(x));
            }
            o.insert("membership", asy.membership.to_json());
            root.insert(cfg.label.clone(), Json::Obj(o));
        }
    }
    let dir = out_dir(args).join("churn");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("summary.json");
    std::fs::write(&path, crate::manifest::json::write(&Json::Obj(root)))?;
    println!("# wrote churn study summary to {}", path.display());
    Ok(0)
}

fn cmd_inspect(args: &Args) -> Result<i32> {
    let dir = args.flag("artifacts").unwrap_or("artifacts");
    let m = Manifest::load(dir)?;
    println!("# manifest at {dir}");
    println!("models:");
    for (name, meta) in &m.models {
        println!(
            "  {name:<12} flat {:>9} params in {:>2} tensors, data {:?} {:?}, classes {}",
            meta.flat_size,
            meta.params.len(),
            meta.data_shape,
            meta.x_dtype,
            meta.classes
        );
    }
    println!("artifacts:");
    for (name, a) in &m.artifacts {
        println!(
            "  {name:<26} {:?} batch {:>4} inputs {:>3} outputs {:>3}",
            a.kind,
            a.batch,
            a.inputs.len(),
            a.outputs.len()
        );
    }
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn parse_flags_and_positionals() {
        let a = Args::parse(&argv("table 4.1 --scale 5 --verbose --out results")).unwrap();
        assert_eq!(a.positional, vec!["table", "4.1"]);
        assert_eq!(a.flag("scale"), Some("5"));
        assert!(a.has("verbose"));
        assert_eq!(a.flag("out"), Some("results"));
        assert!(Args::parse(&argv("x --scale")).is_err());
    }

    #[test]
    fn flag_parse_types() {
        let a = Args::parse(&argv("--epochs 7")).unwrap();
        assert_eq!(a.flag_parse("epochs", 3usize).unwrap(), 7);
        assert_eq!(a.flag_parse("missing", 3usize).unwrap(), 3);
        let bad = Args::parse(&argv("--epochs seven")).unwrap();
        assert!(bad.flag_parse("epochs", 3usize).is_err());
    }

    #[test]
    fn table_label_sets() {
        assert_eq!(table_labels("4.1").unwrap().len(), 16);
        assert_eq!(table_labels("4.2").unwrap().len(), 13);
        assert_eq!(table_labels("4.3").unwrap().len(), 9);
        assert_eq!(table_labels("a.1").unwrap().len(), 8);
        assert!(table_labels("9.9").is_err());
    }

    #[test]
    fn figure_label_sets() {
        assert_eq!(figure_labels("4.1").unwrap(), vec!["SGD-1"]);
        assert!(figure_labels("4.3").unwrap().len() >= 14);
        assert!(figure_labels("5.5").is_err());
    }

    #[test]
    fn common_flags_scale() {
        let args = Args::parse(&argv("--scale 10 --epochs 2 --synthetic --seed 9")).unwrap();
        let cfg = apply_common_flags(ExperimentConfig::preset("EG-4-0.031").unwrap(), &args).unwrap();
        assert_eq!(cfg.epochs, 2);
        assert_eq!(cfg.seed, 9);
        assert_eq!(cfg.n_train, 5120);
        assert!(matches!(cfg.engine, EngineKind::Synthetic { .. }));
    }

    #[test]
    fn codec_flag_applies() {
        use crate::comm::codec::CodecKind;
        let args = Args::parse(&argv("--codec topk:0.01")).unwrap();
        let cfg = apply_common_flags(ExperimentConfig::preset("EG-4-0.031").unwrap(), &args).unwrap();
        assert_eq!(cfg.codec, CodecKind::TopK { frac: 0.01 });
        let bad = Args::parse(&argv("--codec zstd")).unwrap();
        assert!(apply_common_flags(ExperimentConfig::default(), &bad).is_err());
    }

    #[test]
    fn churn_flag_applies() {
        let args = Args::parse(&argv("--churn crash@35%:1,rejoin@75%:1")).unwrap();
        let cfg = apply_common_flags(ExperimentConfig::preset("EG-4-0.031").unwrap(), &args).unwrap();
        assert!(!cfg.churn.is_empty());
        assert_eq!(cfg.churn.label(), "crash@35%:1,rejoin@75%:1");
        let bad = Args::parse(&argv("--churn explode@1:1")).unwrap();
        assert!(apply_common_flags(ExperimentConfig::default(), &bad).is_err());
    }

    #[test]
    fn faults_and_fd_flags_apply() {
        let args =
            Args::parse(&argv("--faults drop:0.05,jitter:0.5,seed:11 --fd on")).unwrap();
        let cfg = apply_common_flags(ExperimentConfig::preset("EG-4-0.031").unwrap(), &args).unwrap();
        assert!(!cfg.faults.is_empty());
        assert_eq!(cfg.faults.label(), "drop:0.05,jitter:0.5,seed:11");
        assert!(!cfg.fd.is_empty());
        // diagnostics name the offending token and its position
        let bad = Args::parse(&argv("--faults drop:0.05,jetter:0.5")).unwrap();
        let err = apply_common_flags(ExperimentConfig::default(), &bad).unwrap_err();
        assert!(err.to_string().contains("jetter:0.5"), "{err}");
        assert!(err.to_string().contains("clause 2"), "{err}");
        let bad = Args::parse(&argv("--fd 0.25:0.3:fast:2")).unwrap();
        assert!(apply_common_flags(ExperimentConfig::default(), &bad).is_err());
    }

    #[test]
    fn trace_flag_applies() {
        let args = Args::parse(&argv("--trace on,ring:512")).unwrap();
        let cfg = apply_common_flags(ExperimentConfig::preset("EG-4-0.031").unwrap(), &args).unwrap();
        assert!(!cfg.trace.is_off());
        assert_eq!(cfg.trace.ring, 512);
        assert_eq!(cfg.trace.label(), "on,ring:512");
        // default stays off (the zero-overhead path)
        let none = Args::parse(&argv("train")).unwrap();
        let cfg = apply_common_flags(ExperimentConfig::preset("EG-4-0.031").unwrap(), &none).unwrap();
        assert!(cfg.trace.is_off());
        let bad = Args::parse(&argv("--trace sometimes")).unwrap();
        assert!(apply_common_flags(ExperimentConfig::default(), &bad).is_err());
    }

    #[test]
    fn full_flag_restores_paper_scale() {
        let args = Args::parse(&argv("--full")).unwrap();
        let cfg = apply_common_flags(ExperimentConfig::preset("EG-4-0.031").unwrap(), &args).unwrap();
        assert_eq!(cfg.n_train, 51_200);
        assert_eq!(cfg.epochs, 100);
    }
}
