//! The paper's reported numbers, embedded so every harness prints
//! paper-vs-measured side by side.
//!
//! Sources: Table 4.1 (MNIST method comparison), Table 4.2 (moving-rate
//! sweep), Table 4.3 (CIFAR-10), Table A.1 (period vs probability).
//! `None` = the paper leaves the cell blank (e.g. aggregate accuracy for
//! All-reduce, where replicas are identical by construction).

/// (label, rank0_accuracy, aggregate_accuracy)
pub type Row = (&'static str, f32, Option<f32>);

pub const TABLE_4_1: &[Row] = &[
    ("AR-4", 0.9861, None),
    ("NC-4", 0.9723, None),
    ("EG-4-0.125", 0.9862, Some(0.9861)),
    ("GS-4-0.125", 0.9855, Some(0.9850)),
    ("EG-4-0.031", 0.9861, Some(0.9862)),
    ("GS-4-0.031", 0.9849, Some(0.9850)),
    ("EG-4-0.008", 0.9838, Some(0.9853)),
    ("GS-4-0.008", 0.9830, Some(0.9847)),
    ("EG-4-0.002", 0.9847, Some(0.9844)),
    ("GS-4-0.002", 0.9823, Some(0.9829)),
    ("EG-8-0.031", 0.9845, Some(0.9854)),
    ("GS-8-0.031", 0.9838, Some(0.9842)),
    ("EG-8-0.008", 0.9850, Some(0.9852)),
    ("GS-8-0.008", 0.9820, Some(0.9824)),
    ("EG-8-0.002", 0.9772, Some(0.9812)),
    ("GS-8-0.002", 0.9767, Some(0.9778)),
];

pub const TABLE_4_2: &[Row] = &[
    ("EG-4-0.0312-0.05", 0.9833, Some(0.9850)),
    ("EG-4-0.0312-0.25", 0.9860, Some(0.9865)),
    ("EG-4-0.0312-0.50", 0.9861, Some(0.9862)),
    ("EG-4-0.0312-0.75", 0.9846, Some(0.9850)),
    ("EG-4-0.0312-0.95", 0.9846, Some(0.9857)),
    ("EG-4-0.0005-0.05", 0.9752, Some(0.9647)),
    ("EG-4-0.0005-0.25", 0.9816, Some(0.9826)),
    ("EG-4-0.0005-0.50", 0.9814, Some(0.9834)),
    ("EG-4-0.0005-0.75", 0.9813, Some(0.9825)),
    ("EG-4-0.0005-0.95", 0.9801, Some(0.9765)),
    ("EG-8-0.0005-0.05", 0.9532, Some(0.4309)),
    ("EG-8-0.0005-0.25", 0.9719, Some(0.9708)),
    ("EG-8-0.0005-0.50", 0.9722, Some(0.9747)),
];

pub const TABLE_4_3: &[Row] = &[
    ("CIFAR-AR-4", 0.9193, Some(0.9193)),
    ("CIFAR-EG-4-0.125", 0.9166, Some(0.9146)),
    ("CIFAR-GS-4-0.125", 0.9131, Some(0.9135)),
    ("CIFAR-EG-4-0.031", 0.9122, Some(0.9139)),
    ("CIFAR-GS-4-0.031", 0.9048, Some(0.9065)),
    ("CIFAR-EG-4-0.008", 0.9006, Some(0.9044)),
    ("CIFAR-GS-4-0.008", 0.9015, Some(0.9050)),
    ("CIFAR-EG-4-0.002", 0.8952, Some(0.8983)),
    ("CIFAR-GS-4-0.002", 0.8825, Some(0.8845)),
];

/// Table A.1 pairs each fixed-period run with its probability-matched
/// counterpart (tau_eff = 1/p).
pub const TABLE_A_1: &[Row] = &[
    ("GS-4-TAU-8", 0.9864, Some(0.9865)),
    ("GS-4-0.125", 0.9855, Some(0.9850)),
    ("GS-4-TAU-32", 0.9857, Some(0.9858)),
    ("GS-4-0.031", 0.9849, Some(0.9850)),
    ("GS-4-TAU-128", 0.9846, Some(0.9848)),
    ("GS-4-0.008", 0.9830, Some(0.9847)),
    ("GS-4-TAU-512", 0.9833, Some(0.9843)),
    ("GS-4-0.002", 0.9823, Some(0.9829)),
];

/// Single-worker baseline band (§4.1.1: 98.51%–98.61% across 4 seeds).
pub const BASELINE_RANGE: (f32, f32) = (0.9851, 0.9861);

pub fn lookup(table: &[Row], label: &str) -> Option<Row> {
    table.iter().find(|(l, _, _)| *l == label).copied()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    #[test]
    fn every_reference_row_has_a_preset() {
        let presets: Vec<String> = ExperimentConfig::all_presets()
            .iter()
            .map(|c| c.label.clone())
            .collect();
        for table in [TABLE_4_1, TABLE_4_2, TABLE_4_3, TABLE_A_1] {
            for (label, _, _) in table {
                assert!(presets.iter().any(|p| p == label), "no preset for {label}");
            }
        }
    }

    #[test]
    fn lookup_works() {
        assert_eq!(lookup(TABLE_4_1, "AR-4").unwrap().1, 0.9861);
        assert!(lookup(TABLE_4_1, "nope").is_none());
    }
}
