//! Minimal recursive-descent JSON parser + writer.
//!
//! The vendored dependency set has no `serde`/`serde_json`, so the crate
//! carries its own small, strict JSON implementation.  It supports the
//! full JSON grammar (objects, arrays, strings with escapes, numbers,
//! bools, null) and preserves object key order — enough for
//! `artifacts/manifest.json`, `fixtures.json`, and our own metric dumps.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.  Object keys additionally keep insertion order in
/// `Object`'s companion `keys` vector so that round-trips are stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(JsonObj),
}

/// Order-preserving JSON object.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct JsonObj {
    map: BTreeMap<String, Json>,
    order: Vec<String>,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn insert(&mut self, k: impl Into<String>, v: Json) {
        let k = k.into();
        if !self.map.contains_key(&k) {
            self.order.push(k.clone());
        }
        self.map.insert(k, v);
    }
    pub fn get(&self, k: &str) -> Option<&Json> {
        self.map.get(k)
    }
    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.order.iter()
    }
    pub fn len(&self) -> usize {
        self.map.len()
    }
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&JsonObj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["a"]["b"]` style access; returns Null on any miss.
    pub fn path(&self, keys: &[&str]) -> &Json {
        let mut cur = self;
        for k in keys {
            match cur {
                Json::Obj(o) => match o.get(k) {
                    Some(v) => cur = v,
                    None => return &Json::Null,
                },
                _ => return &Json::Null,
            }
        }
        cur
    }
}

// ---------------------------------------------------------------------------
// parser
// ---------------------------------------------------------------------------

pub fn parse(src: &str) -> Result<Json, String> {
    let mut p = Parser {
        bytes: src.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, String> {
        Err(format!("json error at byte {}: {}", self.pos, msg))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.err(&format!("expected '{}'", c as char))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            self.err(&format!("expected literal {s}"))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("unexpected character"),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut obj = JsonObj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            obj.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(obj)),
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("bad \\u escape")? as char;
                            code = code * 16 + c.to_digit(16).ok_or("bad hex")?;
                        }
                        // (surrogate pairs unsupported — not produced by our writers)
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return self.err("bad escape"),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: copy raw continuation bytes
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        return self.err("truncated utf8");
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {s}: {e}"))
    }
}

// ---------------------------------------------------------------------------
// writer
// ---------------------------------------------------------------------------

pub fn write(v: &Json) -> String {
    let mut out = String::new();
    write_into(v, &mut out);
    out
}

fn write_into(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Json::Str(s) => write_str(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(x, out);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, k) in o.keys().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_str(k, out);
                out.push(':');
                write_into(o.get(k).unwrap(), out);
            }
            out.push('}');
        }
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse(" null ").unwrap(), Json::Null);
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.path(&["a"]).as_arr().unwrap().len(), 3);
        assert_eq!(v.path(&["a"]).as_arr().unwrap()[2].path(&["b"]).as_str(), Some("x"));
        assert_eq!(v.path(&["c"]), &Json::Bool(false));
        assert_eq!(v.path(&["missing"]), &Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("'single'").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"s":"he\"llo","n":3.25,"i":7,"a":[true,null],"o":{"k":1}}"#;
        let v = parse(src).unwrap();
        let out = write(&v);
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn object_key_order_preserved() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().keys().cloned().collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn unicode_strings() {
        let v = parse(r#""héllo — ünïcode""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo — ünïcode"));
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }
}
