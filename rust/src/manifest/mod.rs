//! Typed view over `artifacts/manifest.json` (written by `compile/aot.py`).
//!
//! The manifest is the contract between the python AOT path and the rust
//! runtime: for every HLO artifact it records the exact positional input
//! and output tensor specs, and for every model the ordered parameter
//! layout (the segmentation of the flat f32 parameter buffer the
//! coordinator trains on).

pub mod json;

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use json::Json;

/// Element type of a tensor in an artifact signature.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    U32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => Dtype::F32,
            "i32" => Dtype::I32,
            "u32" => Dtype::U32,
            other => bail!("unknown dtype {other}"),
        })
    }
    pub fn size_bytes(self) -> usize {
        4
    }
}

/// One positional tensor in an artifact signature.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn num_elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// What a given artifact computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// (params..., x, y, seed) -> (loss, grads...)
    Train,
    /// vmapped over workers: (stacked params..., x, y, seeds) ->
    /// (losses, stacked grads...) — one call per synchronized step
    TrainStacked,
    /// (params..., x, y, mask) -> (sum_loss, num_correct)
    Eval,
    /// (theta_i, theta_k, alpha) -> (theta_i', theta_k')
    Gossip,
    /// (theta, v, g, eta, mu) -> (theta', v')
    Nag,
}

/// One AOT-compiled HLO artifact.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub file: PathBuf,
    pub kind: ArtifactKind,
    pub model: Option<String>,
    pub batch: usize,
    /// worker count for TrainStacked artifacts (1 otherwise)
    pub workers: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// One named parameter tensor of a model.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub size: usize,
    /// offset into the flat parameter buffer
    pub offset: usize,
}

/// A model's parameter layout + data signature.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    pub params: Vec<ParamSpec>,
    pub flat_size: usize,
    pub data_shape: Vec<usize>,
    pub x_dtype: Dtype,
    pub classes: usize,
    pub init_file: Option<PathBuf>,
}

/// The full parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelMeta>,
    pub artifacts: BTreeMap<String, Artifact>,
}

fn tensor_spec(v: &Json) -> Result<TensorSpec> {
    let name = v
        .path(&["name"])
        .as_str()
        .ok_or_else(|| anyhow!("tensor spec missing name"))?
        .to_string();
    let shape = v
        .path(&["shape"])
        .as_arr()
        .ok_or_else(|| anyhow!("tensor {name}: missing shape"))?
        .iter()
        .map(|s| s.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = Dtype::parse(
        v.path(&["dtype"])
            .as_str()
            .ok_or_else(|| anyhow!("tensor {name}: missing dtype"))?,
    )?;
    Ok(TensorSpec { name, shape, dtype })
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let root = json::parse(&src).map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;

        let mut models = BTreeMap::new();
        if let Some(obj) = root.path(&["models"]).as_obj() {
            for name in obj.keys() {
                let m = obj.get(name).unwrap();
                let mut offset = 0usize;
                let mut params = Vec::new();
                for p in m.path(&["params"]).as_arr().unwrap_or(&[]) {
                    let size = p.path(&["size"]).as_usize().unwrap_or(0);
                    params.push(ParamSpec {
                        name: p.path(&["name"]).as_str().unwrap_or("").to_string(),
                        shape: p
                            .path(&["shape"])
                            .as_arr()
                            .unwrap_or(&[])
                            .iter()
                            .filter_map(|d| d.as_usize())
                            .collect(),
                        size,
                        offset,
                    });
                    offset += size;
                }
                let flat_size = m.path(&["flat_size"]).as_usize().unwrap_or(0);
                if offset != flat_size {
                    bail!("model {name}: param sizes sum to {offset} != flat_size {flat_size}");
                }
                models.insert(
                    name.clone(),
                    ModelMeta {
                        name: name.clone(),
                        params,
                        flat_size,
                        data_shape: m
                            .path(&["data_shape"])
                            .as_arr()
                            .unwrap_or(&[])
                            .iter()
                            .filter_map(|d| d.as_usize())
                            .collect(),
                        x_dtype: Dtype::parse(m.path(&["x_dtype"]).as_str().unwrap_or("f32"))?,
                        classes: m.path(&["classes"]).as_usize().unwrap_or(0),
                        init_file: m
                            .path(&["init_file"])
                            .as_str()
                            .map(|f| dir.join(f)),
                    },
                );
            }
        }

        let mut artifacts = BTreeMap::new();
        if let Some(obj) = root.path(&["artifacts"]).as_obj() {
            for name in obj.keys() {
                let a = obj.get(name).unwrap();
                let kind = match a.path(&["kind"]).as_str() {
                    Some("train") => ArtifactKind::Train,
                    Some("train_stacked") => ArtifactKind::TrainStacked,
                    Some("eval") => ArtifactKind::Eval,
                    Some("gossip") => ArtifactKind::Gossip,
                    Some("nag") => ArtifactKind::Nag,
                    other => bail!("artifact {name}: unknown kind {other:?}"),
                };
                artifacts.insert(
                    name.clone(),
                    Artifact {
                        name: name.clone(),
                        file: dir.join(
                            a.path(&["file"])
                                .as_str()
                                .ok_or_else(|| anyhow!("artifact {name}: missing file"))?,
                        ),
                        kind,
                        model: a.path(&["model"]).as_str().map(str::to_string),
                        batch: a.path(&["batch"]).as_usize().unwrap_or(0),
                        workers: a.path(&["workers"]).as_usize().unwrap_or(1),
                        inputs: a
                            .path(&["inputs"])
                            .as_arr()
                            .unwrap_or(&[])
                            .iter()
                            .map(tensor_spec)
                            .collect::<Result<Vec<_>>>()?,
                        outputs: a
                            .path(&["outputs"])
                            .as_arr()
                            .unwrap_or(&[])
                            .iter()
                            .map(tensor_spec)
                            .collect::<Result<Vec<_>>>()?,
                    },
                );
            }
        }

        Ok(Manifest {
            dir,
            models,
            artifacts,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model {name} not in manifest"))
    }

    /// The train artifact for `model` at exactly `batch`.
    pub fn train_artifact(&self, model: &str, batch: usize) -> Result<&Artifact> {
        self.artifacts
            .values()
            .find(|a| a.kind == ArtifactKind::Train && a.model.as_deref() == Some(model) && a.batch == batch)
            .ok_or_else(|| {
                let have: Vec<usize> = self.train_batches(model);
                anyhow!("no train artifact for {model} at batch {batch}; available: {have:?}")
            })
    }

    /// All train batch sizes available for `model`, ascending.
    pub fn train_batches(&self, model: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .values()
            .filter(|a| a.kind == ArtifactKind::Train && a.model.as_deref() == Some(model))
            .map(|a| a.batch)
            .collect();
        v.sort();
        v
    }

    /// The stacked train artifact for `model` at (workers, batch), if lowered.
    pub fn stacked_train_artifact(&self, model: &str, workers: usize, batch: usize) -> Option<&Artifact> {
        self.artifacts.values().find(|a| {
            a.kind == ArtifactKind::TrainStacked
                && a.model.as_deref() == Some(model)
                && a.batch == batch
                && a.workers == workers
        })
    }

    /// The (single) eval artifact for `model`.
    pub fn eval_artifact(&self, model: &str) -> Result<&Artifact> {
        self.artifacts
            .values()
            .find(|a| a.kind == ArtifactKind::Eval && a.model.as_deref() == Some(model))
            .ok_or_else(|| anyhow!("no eval artifact for {model}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_manifest() -> &'static str {
        r#"{
          "version": 1,
          "models": {
            "m": {"params": [{"name":"w","shape":[2,3],"size":6},
                              {"name":"b","shape":[3],"size":3}],
                   "flat_size": 9, "data_shape": [2], "x_dtype": "f32",
                   "classes": 3, "kind": "MlpConfig"}
          },
          "artifacts": {
            "m_train_b4": {"file":"m_train_b4.hlo.txt","kind":"train","model":"m","batch":4,
              "inputs":[{"name":"w","shape":[2,3],"dtype":"f32"},
                        {"name":"b","shape":[3],"dtype":"f32"},
                        {"name":"x","shape":[4,2],"dtype":"f32"},
                        {"name":"y","shape":[4],"dtype":"i32"},
                        {"name":"seed","shape":[],"dtype":"i32"}],
              "outputs":[{"name":"loss","shape":[],"dtype":"f32"}]},
            "m_eval_b8": {"file":"m_eval_b8.hlo.txt","kind":"eval","model":"m","batch":8,
              "inputs":[],"outputs":[]}
          }
        }"#
    }

    fn load_tiny() -> Manifest {
        let dir = std::env::temp_dir().join(format!("eg-manifest-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), tiny_manifest()).unwrap();
        Manifest::load(&dir).unwrap()
    }

    #[test]
    fn parses_models_and_offsets() {
        let m = load_tiny();
        let model = m.model("m").unwrap();
        assert_eq!(model.flat_size, 9);
        assert_eq!(model.params[0].offset, 0);
        assert_eq!(model.params[1].offset, 6);
        assert_eq!(model.x_dtype, Dtype::F32);
    }

    #[test]
    fn finds_artifacts_by_batch() {
        let m = load_tiny();
        let a = m.train_artifact("m", 4).unwrap();
        assert_eq!(a.inputs.len(), 5);
        assert_eq!(a.inputs[3].dtype, Dtype::I32);
        assert!(m.train_artifact("m", 31).is_err());
        assert_eq!(m.train_batches("m"), vec![4]);
        assert_eq!(m.eval_artifact("m").unwrap().batch, 8);
    }

    #[test]
    fn flat_size_mismatch_rejected() {
        let dir = std::env::temp_dir().join(format!("eg-manifest-bad-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let bad = tiny_manifest().replace("\"flat_size\": 9", "\"flat_size\": 10");
        std::fs::write(dir.join("manifest.json"), bad).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn loads_real_manifest_if_present() {
        // integration sanity when artifacts/ has been built
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.models.contains_key("mlp_paper"));
            let paper = m.model("mlp_paper").unwrap();
            assert_eq!(paper.flat_size, 784 * 1024 + 1024 + 2 * (1024 * 1024 + 1024) + 1024 * 10 + 10);
            assert!(!m.train_batches("mlp_paper").is_empty());
        }
    }
}
