# Canonical entry points for builders and CI.
#
#   just verify       — tier-1: release build + full test suite
#   just perf-smoke   — release-mode perf probe (comm round / grad dispatch)
#   just bench-comm   — comm-cost bench; writes BENCH_comm.json
#   just bench-kernels— kernel dispatch bench; writes BENCH_kernels.json
#   just bench-wire   — wire-codec bench; writes BENCH_wire.json
#   just bench-churn  — membership bench; writes BENCH_churn.json
#   just bench-fd     — failure-detector bench; writes BENCH_fd.json
#   just bench-scale  — sharded-queue scale bench; writes BENCH_scale.json
#   just bench-net    — sim-vs-wire UDP bench; writes BENCH_net.json
#   just trace-smoke  — traced run -> schema-validated Chrome trace JSON
#   just regen-golden — re-bless the golden trajectory fixtures
#
# No `just` on the box? The recipes are one-liners — copy them verbatim.

default: verify

# tier-1 gate: must stay green (ROADMAP.md)
verify:
    cd rust && cargo build --release && cargo test -q

# quick perf sanity on the communication hot path
perf-smoke:
    cd rust && cargo run --release --example perf_probe

# full comm-cost tables + BENCH_comm.json for the perf trajectory
bench-comm:
    cd rust && cargo bench --bench comm_cost

# kernel-level micro-benches: scalar vs runtime-dispatched SIMD for every
# tensor::simd kernel (writes BENCH_kernels.json), plus the fused
# multi-peer elastic update, NAG and all-reduce comparisons
bench-kernels:
    cd rust && cargo bench --bench kernels

# wire-codec bench: encoded bytes + throughput, identity vs q8 vs topk;
# writes BENCH_wire.json next to BENCH_comm.json
bench-wire:
    cd rust && cargo bench --bench comm_cost -- wire

# elastic-membership bench: async throughput + dropped-bytes ledger under
# the standard crash/rejoin schedule; writes BENCH_churn.json
bench-churn:
    cd rust && cargo bench --bench comm_cost -- churn

# failure-detector bench: detection latency + suspicion counts across a
# link-loss sweep with the membership oracle off; writes BENCH_fd.json
bench-fd:
    cd rust && cargo bench --bench comm_cost -- fd

# fleet-scale study: nodes × shards events/sec, peak RSS, cross-shard
# message fraction on the sharded event queue; writes BENCH_scale.json
bench-scale:
    cd rust && cargo run --release --example scale_study -- --bench

# sim-vs-wire study: loopback-UDP conformance digests + a free-running
# wall-clock UDP fleet vs the virtual-clock straggler model; writes
# BENCH_net.json (a skip marker where loopback sockets are forbidden)
bench-net:
    cd rust && cargo run --release --example net_study -- --bench

# observability smoke: run a small traced async study and validate the
# emitted flight-recorder JSON against the Chrome trace-event schema
# (`repro trace-dump` fails on any malformed event); the dump lands
# under results/trace/ and loads in Perfetto / chrome://tracing
trace-smoke:
    cd rust && cargo run --release --bin repro -- trace-dump --workers 4 --epochs 2

# re-bless the golden trajectory fixtures (tests/fixtures/golden/) after an
# INTENTIONAL trajectory change; commit the updated fixtures with the PR
regen-golden:
    cd rust && REGEN_GOLDEN=1 cargo test --release --test golden -- --nocapture

# nightly-strength property testing: 10x the per-commit case counts
proptest-deep:
    cd rust && EG_PROPTEST_CASES_X=10 cargo test --release --test proptests
