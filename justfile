# Canonical entry points for builders and CI.
#
#   just verify      — tier-1: release build + full test suite
#   just perf-smoke  — release-mode perf probe (comm round / grad dispatch)
#   just bench-comm  — comm-cost bench; writes BENCH_comm.json
#
# No `just` on the box? The recipes are one-liners — copy them verbatim.

default: verify

# tier-1 gate: must stay green (ROADMAP.md)
verify:
    cd rust && cargo build --release && cargo test -q

# quick perf sanity on the communication hot path
perf-smoke:
    cd rust && cargo run --release --example perf_probe

# full comm-cost tables + BENCH_comm.json for the perf trajectory
bench-comm:
    cd rust && cargo bench --bench comm_cost

# kernel-level micro-benches (fused multi-peer elastic update, NAG, all-reduce)
bench-kernels:
    cd rust && cargo bench --bench kernels
