//! Quickstart: train a compiled MLP with 4-worker Elastic Gossip.
//!
//! ```bash
//! make artifacts            # once: python AOT -> artifacts/*.hlo.txt
//! cargo run --release --example quickstart
//! ```
//!
//! Uses the small AOT model (`mlp_small`, 8970 params) on a synthetic
//! 10-class task so the whole thing finishes in seconds, and compares
//! Elastic Gossip against the no-communication lower bound — the
//! smallest possible version of the paper's core claim.

use elastic_gossip::config::{CommSchedule, DatasetKind, EngineKind, ExperimentConfig};
use elastic_gossip::coordinator::run_experiment_verbose;
use elastic_gossip::prelude::*;

fn main() -> anyhow::Result<()> {
    let base = ExperimentConfig {
        label: "quickstart".into(),
        workers: 4,
        schedule: CommSchedule::Probability(0.125),
        engine: EngineKind::Hlo { model: "mlp_small".into() },
        dataset: DatasetKind::SyntheticVectors { dim: 64 },
        n_train: 4096,
        n_val: 512,
        n_test: 512,
        effective_batch: 32,
        epochs: 6,
        seed: 0,
        ..ExperimentConfig::default()
    };

    println!("== Elastic Gossip quickstart: 4 workers, p = 0.125, alpha = 0.5 ==\n");
    let mut results = Vec::new();
    for (name, method) in [
        ("elastic-gossip", Method::ElasticGossip { alpha: 0.5 }),
        ("no-communication", Method::NoComm),
    ] {
        let cfg = ExperimentConfig {
            label: name.into(),
            method,
            ..base.clone()
        };
        let report = run_experiment_verbose(&cfg, true)?;
        results.push((name, report));
    }

    println!("\n{:<20} {:>12} {:>12} {:>12}", "method", "rank0-acc", "agg-acc", "comm-KB");
    for (name, r) in &results {
        println!(
            "{:<20} {:>12.4} {:>12.4} {:>12.1}",
            name,
            r.rank0_accuracy,
            r.aggregate_accuracy,
            r.metrics.comm_bytes as f64 / 1e3
        );
    }
    let (eg, nc) = (&results[0].1, &results[1].1);
    println!(
        "\nElastic Gossip beats the no-communication bound by {:+.2} points\n\
         while gossiping only every ~{:.0} steps per worker.",
        100.0 * (eg.rank0_accuracy - nc.rank0_accuracy),
        1.0 / 0.125
    );
    Ok(())
}
