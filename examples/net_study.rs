//! Sim-vs-wire study: what does a **real UDP transport** change, and
//! what must it not change?
//!
//! PR 9 puts real 127.0.0.1 sockets behind the Fabric seam in two forms:
//!
//! * `transport: loopback-udp` — the virtual-clock simulator still makes
//!   every decision (schedules, picks, delivery times) but each payload
//!   is round-tripped through a real socket.  At zero induced loss this
//!   must be **bit-identical** to `inproc`; part 1 asserts the digests.
//! * `repro net-train` — free-running worker loops paced by the wall
//!   clock, gossiping over UDP with no simulator in the loop.  Runs are
//!   reproducible in **aggregate** (same data, schedule tables and
//!   protocol), not bit-identical across runs.  Part 2 drives the same
//!   worker loop on threads (same sockets as the spawned-process form,
//!   without needing a prebuilt binary path) and compares its measured
//!   staleness against the virtual-clock straggler model.
//!
//! Network-gated: a sandbox that forbids binding loopback sockets gets a
//! visible `skipped: no network` note (and, under `--bench`, a
//! BENCH_net.json that says so) instead of a failure.
//!
//! ```bash
//! cargo run --release --example net_study              # full study
//! cargo run --release --example net_study -- --quick   # CI smoke
//! cargo run --release --example net_study -- --bench   # + BENCH_net.json
//! ```

use std::time::Instant;

use elastic_gossip::algos::Method;
use elastic_gossip::comm::codec::CodecKind;
use elastic_gossip::comm::transport::{probe_loopback, TransportKind};
use elastic_gossip::manifest::json::{self, Json, JsonObj};
use elastic_gossip::membership::digest_params;
use elastic_gossip::runtime_async::net::{collect_summaries, run_net_worker, NetTrainCfg};
use elastic_gossip::runtime_async::{run_async, study_setup, AsyncRunReport, AsyncSimCfg};

/// One in-process run at the given transport.
fn run_with(method: &str, codec: &str, transport: TransportKind, sim: &AsyncSimCfg) -> AsyncRunReport {
    let m = Method::parse(method).expect("method");
    let (mut cfg, spec) = study_setup(m, sim.speeds.len(), 0.25, 2, 11);
    cfg.codec = CodecKind::parse(codec).expect("codec");
    cfg.transport = transport;
    run_async(&cfg, &spec, sim).expect("run_async")
}

fn digests(r: &AsyncRunReport) -> Vec<u64> {
    r.final_params.iter().map(|p| digest_params(p)).collect()
}

fn obj_num(v: &Json, key: &str) -> f64 {
    v.as_obj().and_then(|o| o.get(key)).and_then(Json::as_f64).unwrap_or(f64::NAN)
}

fn nested_num(v: &Json, outer: &str, key: &str) -> f64 {
    v.as_obj()
        .and_then(|o| o.get(outer))
        .map(|inner| obj_num(inner, key))
        .unwrap_or(f64::NAN)
}

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let quick = argv.iter().any(|a| a == "--quick");
    let bench = argv.iter().any(|a| a == "--bench");

    if !probe_loopback() {
        println!("net_study skipped: no network (loopback socket bind forbidden)");
        if bench {
            let mut root = JsonObj::new();
            root.insert("bench", Json::Str("net".into()));
            root.insert("skipped", Json::Str("no network".into()));
            match std::fs::write("BENCH_net.json", json::write(&Json::Obj(root))) {
                Ok(()) => println!("wrote BENCH_net.json (skip marker)"),
                Err(e) => eprintln!("could not write BENCH_net.json: {e}"),
            }
        }
        return;
    }

    println!("== sim vs wire: real UDP behind the Fabric seam ==\n");

    // --- part 1: conformance — the wire must change nothing --------------
    // The loopback-UDP splice keeps the simulator in charge; at zero loss
    // the digests must match the pure in-process run exactly.
    let conf_cases: &[(&str, &str)] = if quick {
        &[("elastic-gossip:0.5", "identity")]
    } else {
        &[
            ("elastic-gossip:0.5", "identity"),
            ("elastic-gossip:0.5", "q8:64"),
            ("gossip-pull", "identity"),
            ("gosgd", "q4:64"),
        ]
    };
    println!("conformance (lockstep, 3 nodes): inproc vs loopback-udp");
    let mut conf_rows: Vec<Json> = Vec::new();
    for (method, codec) in conf_cases {
        let sim = AsyncSimCfg::lockstep(3);
        let a = run_with(method, codec, TransportKind::InProc, &sim);
        let b = run_with(method, codec, TransportKind::LoopbackUdp, &sim);
        let ok = digests(&a) == digests(&b);
        assert!(ok, "{method}/{codec}: wire run diverged from inproc");
        println!("  {method:<20} {codec:<10} digest match: yes");
        let mut o = JsonObj::new();
        o.insert("method", Json::Str((*method).into()));
        o.insert("codec", Json::Str((*codec).into()));
        o.insert("digest_match", Json::Num(1.0));
        conf_rows.push(Json::Obj(o));
    }

    // --- part 2: free-running UDP fleet vs virtual-clock model ------------
    // Same worker count, pacing and straggler shape on both sides; the
    // question is how well the simulator's staleness model predicts what a
    // wall-clock fleet actually measures.
    let (w, epochs, pace_ms, straggler) =
        if quick { (2usize, 2usize, 5u64, 1.0f64) } else { (4, 3, 10, 2.0) };
    let base = std::env::temp_dir().join(format!("eg_net_study_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    let nc = NetTrainCfg {
        method: Method::parse("elastic-gossip:0.5").expect("method"),
        workers: w,
        epochs,
        prob: 0.25,
        seed: 7,
        codec: CodecKind::parse("identity").expect("codec"),
        pace_ms,
        straggler,
        rendezvous: base.join("rendezvous"),
        out: base.join("out"),
        linger_ms: 800,
    };
    for p in [&nc.rendezvous, &nc.out] {
        std::fs::create_dir_all(p).expect("mkdir");
    }

    println!(
        "\nwall-clock fleet: {w} workers x {epochs} epochs, pace {pace_ms} ms, \
         straggler x{straggler}"
    );
    let t0 = Instant::now();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..w)
            .map(|rank| {
                let nc = nc.clone();
                s.spawn(move || run_net_worker(&nc, rank, false))
            })
            .collect();
        for (rank, h) in handles.into_iter().enumerate() {
            h.join().expect("worker thread panicked").unwrap_or_else(|e| {
                panic!("rank {rank} failed: {e}");
            });
        }
    });
    let wire_wall_s = t0.elapsed().as_secs_f64();
    let ranks = collect_summaries(&nc).expect("collect rank summaries");

    // the virtual-clock twin: same shape, simulated time
    let sim_cfgs = AsyncSimCfg::straggler(w, pace_ms as f64 / 1000.0, 0.1, straggler);
    let (mut cfg, spec) = study_setup(nc.method.clone(), w, nc.prob, epochs, nc.seed);
    cfg.codec = nc.codec;
    let sim = run_async(&cfg, &spec, &sim_cfgs).expect("sim run");
    let sim_stale = sim.staleness.to_json();

    println!("\n    rank    steps      acc   stale.mean   lat.mean-ms   frames-sent");
    let mut fleet_rows: Vec<Json> = Vec::new();
    for v in &ranks {
        let (rank, steps) = (obj_num(v, "rank"), obj_num(v, "steps"));
        let acc = obj_num(v, "accuracy");
        let sm = nested_num(v, "staleness", "mean");
        let lm = nested_num(v, "wire_latency", "mean_ms");
        let fs = nested_num(v, "transport", "frames_sent");
        println!("  {rank:>6} {steps:>8} {acc:>8.4} {sm:>12.2} {lm:>13.3} {fs:>13}");
        let mut o = JsonObj::new();
        o.insert("rank", Json::Num(rank));
        o.insert("steps", Json::Num(steps));
        o.insert("accuracy", Json::Num(acc));
        o.insert("stale_mean", Json::Num(sm));
        o.insert("lat_mean_ms", Json::Num(lm));
        o.insert("frames_sent", Json::Num(fs));
        fleet_rows.push(Json::Obj(o));
    }
    let wire_stale_mean = {
        let (mut num, mut cnt) = (0.0, 0.0);
        for v in &ranks {
            let c = nested_num(v, "staleness", "count");
            let m = nested_num(v, "staleness", "mean");
            if c > 0.0 && m.is_finite() {
                num += m * c;
                cnt += c;
            }
        }
        if cnt > 0.0 { num / cnt } else { 0.0 }
    };
    println!(
        "\nstaleness (steps between snapshot and apply):\n  \
         virtual-clock sim : mean {:.2}  max {}\n  \
         wall-clock UDP    : mean {:.2}  (wall {:.1}s)",
        obj_num(&sim_stale, "mean"),
        obj_num(&sim_stale, "max"),
        wire_stale_mean,
        wire_wall_s
    );
    println!(
        "  sim accuracies    : rank0 {:.4}  aggregate {:.4}",
        sim.report.rank0_accuracy, sim.report.aggregate_accuracy
    );

    // --- artifact ---------------------------------------------------------
    if bench {
        let mut root = JsonObj::new();
        root.insert("bench", Json::Str("net".into()));
        root.insert("conformance", Json::Arr(conf_rows));
        let mut fleet = JsonObj::new();
        fleet.insert("workers", Json::Num(w as f64));
        fleet.insert("epochs", Json::Num(epochs as f64));
        fleet.insert("pace_ms", Json::Num(pace_ms as f64));
        fleet.insert("straggler", Json::Num(straggler));
        fleet.insert("wall_s", Json::Num(wire_wall_s));
        fleet.insert("stale_mean", Json::Num(wire_stale_mean));
        fleet.insert("ranks", Json::Arr(fleet_rows));
        root.insert("fleet", Json::Obj(fleet));
        let mut simj = JsonObj::new();
        simj.insert("stale_mean", Json::Num(obj_num(&sim_stale, "mean")));
        simj.insert("stale_max", Json::Num(obj_num(&sim_stale, "max")));
        simj.insert("rank0_accuracy", Json::Num(sim.report.rank0_accuracy));
        simj.insert("aggregate_accuracy", Json::Num(sim.report.aggregate_accuracy));
        root.insert("sim", Json::Obj(simj));
        let path = "BENCH_net.json";
        match std::fs::write(path, json::write(&Json::Obj(root))) {
            Ok(()) => println!("\nwrote {path}"),
            Err(e) => eprintln!("\ncould not write {path}: {e}"),
        }
    }

    println!(
        "\nreading: the loopback splice is digest-identical to the pure\n\
         in-process run (asserted above) — the wire changes nothing the\n\
         simulator decided.  The free-running fleet is a different regime:\n\
         wall-clock pacing makes runs reproducible in aggregate (same data,\n\
         schedule tables and protocol), not bit-identical, and its measured\n\
         staleness is what the virtual-clock straggler model is trying to\n\
         predict."
    );
    let _ = std::fs::remove_dir_all(&base);
}
