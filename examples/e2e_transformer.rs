//! End-to-end full-stack driver (the reproduction harness's mandated
//! validation run): train a transformer language model for a few hundred
//! steps with 4-worker Elastic Gossip, through every layer of the system:
//!
//!   Pallas fused-dense kernels (L1) → jax transformer fwd/bwd lowered to
//!   HLO (L2) → rust coordinator with gossip matchmaking, NAG, comm
//!   accounting (L3) → PJRT CPU execution.
//!
//! Logs the loss curve to stdout + `results/e2e_transformer/` and asserts
//! the model actually learns (loss well below the ln(256)=5.55 uniform
//! floor).  The recorded run lives in EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example e2e_transformer
//! ```

use elastic_gossip::config::{CommSchedule, DatasetKind, EngineKind, ExperimentConfig};
use elastic_gossip::coordinator::Coordinator;
use elastic_gossip::metrics::write_curves_csv;
use elastic_gossip::prelude::*;
use elastic_gossip::runtime::HloEngineSpec;

fn main() -> anyhow::Result<()> {
    // 4 workers x batch 8 x seq 64; ~300 steps total.
    // lm_small: 469,760 params (d_model 128, 2 layers, 4 heads) — the
    // CPU-tractable substitution documented in DESIGN.md §4.
    let cfg = ExperimentConfig {
        label: "e2e-lm-gossip".into(),
        method: Method::ElasticGossip { alpha: 0.5 },
        workers: 4,
        schedule: CommSchedule::Probability(0.0625),
        optimizer: elastic_gossip::optim::OptimKind::Nag { momentum: 0.9 },
        lr: elastic_gossip::optim::LrSchedule::Const(0.01),
        engine: EngineKind::Hlo { model: "lm_small".into() },
        dataset: DatasetKind::Corpus { seq: 64 },
        n_train: 2048, // windows
        n_val: 128,
        n_test: 128,
        effective_batch: 32, // 8 per worker
        epochs: 5,           // 64 steps/epoch -> 320 steps
        seed: 0,
        eval_every: 1,
        ..ExperimentConfig::default()
    };

    println!("== e2e: byte-LM transformer, 4-worker Elastic Gossip ==");
    println!(
        "   {} steps total ({} per epoch), {} params/worker, p = {:?}\n",
        cfg.total_steps(),
        cfg.steps_per_epoch(),
        469_760,
        cfg.schedule
    );

    let spec = HloEngineSpec {
        artifact_dir: cfg.artifact_dir.clone(),
        model: "lm_small".into(),
        train_batch: cfg.per_worker_batch(),
        workers: 1, // per-worker dispatch (see EXPERIMENTS.md §Perf)
    };
    let mut coord = Coordinator::new(&cfg, &spec);
    coord.verbose = true;
    let report = coord.run()?;

    println!("\nloss curve (mean train loss per epoch):");
    for p in &report.metrics.curve.points {
        let bar_len = ((p.train_loss / 6.0) * 50.0) as usize;
        println!(
            "  epoch {:>2}  loss {:>7.4}  next-byte acc {:>6.4}  |{}",
            p.epoch,
            p.train_loss,
            p.acc_mean(),
            "#".repeat(bar_len.min(60))
        );
    }
    let first = report.metrics.curve.points.first().unwrap().train_loss;
    let last = report.metrics.curve.points.last().unwrap().train_loss;
    println!("\ntrain loss: {first:.4} -> {last:.4}  (uniform floor ln(256) = 5.545)");
    println!("final next-byte accuracy (test, rank-0): {:.4}", report.rank0_accuracy);
    println!("aggregate-model accuracy:                {:.4}", report.aggregate_accuracy);
    println!(
        "gossip traffic: {:.1} MB over {} rounds ({:.2} MB/round)",
        report.metrics.comm_bytes as f64 / 1e6,
        report.metrics.comm_rounds,
        report.metrics.comm_bytes as f64 / 1e6 / report.metrics.comm_rounds.max(1) as f64
    );
    println!("train wall time: {:.1}s", report.metrics.wall_train_s);

    write_curves_csv("results/e2e_transformer", &[report.metrics.curve.clone()])?;
    println!("\ncurve written to results/e2e_transformer/");

    anyhow::ensure!(last < 3.0, "LM failed to learn: final loss {last}");
    println!("OK: all three layers compose; the model learns through the gossip stack.");
    Ok(())
}
