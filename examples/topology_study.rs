//! Topology-aware gossip (thesis future work, §5): how constrained
//! connectivity changes Elastic Gossip's convergence and traffic.
//!
//! The paper assumes a fully-connected topology with uniform link cost;
//! here we run the same experiment over Full / Ring / Torus / random
//! regular graphs and a label-skewed (Dirichlet) partition — the two
//! conditions the conclusion highlights for "inherently distributed
//! systems such as IOT devices and sensor networks".
//!
//! ```bash
//! cargo run --release --example topology_study
//! ```

use elastic_gossip::config::{CommSchedule, DatasetKind, EngineKind, ExperimentConfig};
use elastic_gossip::coordinator::run_experiment;
use elastic_gossip::data::Partition;
use elastic_gossip::prelude::*;

fn main() -> anyhow::Result<()> {
    let w = 8;
    println!("== Elastic Gossip under constrained topologies ({w} workers) ==\n");
    println!(
        "{:<26} {:<14} {:>11} {:>11} {:>10}",
        "topology", "partition", "rank0-acc", "agg-acc", "spread"
    );
    for (tname, topo) in [
        ("full", Topology::Full),
        ("ring", Topology::Ring),
        ("torus 4x2", Topology::Torus2D { width: 4 }),
        ("random 3-regular", Topology::RandomRegular { degree: 3, seed: 5 }),
    ] {
        for (pname, part) in [
            ("iid", Partition::Iid),
            ("dirichlet 0.3", Partition::DirichletSkew { beta: 0.3 }),
        ] {
            let cfg = ExperimentConfig {
                label: format!("topo-{tname}-{pname}"),
                method: Method::ElasticGossip { alpha: 0.5 },
                workers: w,
                schedule: CommSchedule::Probability(0.0625),
                engine: EngineKind::Hlo { model: "mlp_small".into() },
                dataset: DatasetKind::SyntheticVectors { dim: 64 },
                n_train: 4096,
                n_val: 512,
                n_test: 512,
                effective_batch: 64, // 8 per worker
                epochs: 8,
                seed: 0,
                topology: topo.clone(),
                partition: part,
                ..ExperimentConfig::default()
            };
            let report = run_experiment(&cfg)?;
            let spread = report
                .metrics
                .curve
                .last()
                .map(|pt| {
                    let (lo, hi) = pt.acc_range();
                    hi - lo
                })
                .unwrap_or(0.0);
            println!(
                "{:<26} {:<14} {:>11.4} {:>11.4} {:>10.4}",
                tname, pname, report.rank0_accuracy, report.aggregate_accuracy, spread
            );
        }
    }
    println!(
        "\nexpected shape: sparser topologies mix consensus more slowly (larger\n\
         worker spread), and label skew compounds it — full matches the paper's\n\
         setting and serves as the reference row."
    );
    Ok(())
}
