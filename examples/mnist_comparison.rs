//! The paper's core experiment (§4.1.2 / Table 4.1, Figures 4.2–4.3):
//! All-reduce vs Elastic Gossip vs Gossiping SGD vs No-Communication on
//! the permutation-invariant MNIST task (synthetic substitution), using
//! the paper's 784-1024³-10 MLP compiled through the full Pallas → HLO →
//! PJRT stack.
//!
//! ```bash
//! cargo run --release --example mnist_comparison            # scaled down
//! cargo run --release --example mnist_comparison -- --full  # paper scale (slow)
//! ```

use elastic_gossip::cli::paper_ref;
use elastic_gossip::config::{CommSchedule, ExperimentConfig};
use elastic_gossip::coordinator::run_experiment_verbose;
use elastic_gossip::metrics::write_curves_csv;
use elastic_gossip::prelude::*;

fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let labels = ["AR-4", "NC-4", "EG-4-0.031", "GS-4-0.031", "EG-4-0.008", "GS-4-0.008"];

    println!("== Table 4.1 (subset): MNIST-MLP method comparison ==");
    println!("   (synthetic MNIST substitution — orderings, not absolute accuracies)\n");
    println!(
        "{:<14} {:>11} {:>11} {:>11} {:>11} {:>10}",
        "label", "paper-r0", "ours-r0", "paper-agg", "ours-agg", "comm-MB"
    );

    let mut curves = Vec::new();
    for label in labels {
        let mut cfg = ExperimentConfig::preset(label)?;
        if !full {
            cfg = cfg.scaled(10, 5);
        }
        let report = run_experiment_verbose(&cfg, true)?;
        let (_, p_r0, p_agg) = paper_ref::lookup(paper_ref::TABLE_4_1, label).unwrap();
        println!(
            "{:<14} {:>11.4} {:>11.4} {:>11} {:>11.4} {:>10.1}",
            label,
            p_r0,
            report.rank0_accuracy,
            p_agg.map(|a| format!("{a:.4}")).unwrap_or_else(|| "-".into()),
            report.aggregate_accuracy,
            report.metrics.comm_bytes as f64 / 1e6
        );
        curves.push(report.metrics.curve);
    }
    let paths = write_curves_csv("results/mnist_comparison", &curves)?;
    println!("\nwrote {} validation curves (Fig 4.2-style) to results/mnist_comparison/", paths.len());
    println!("expected shape: EG ≈ AR ≳ GS ≫ NC, with gossip at a fraction of AR's traffic");
    Ok(())
}
