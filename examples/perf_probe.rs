//! In-process perf probe: per-worker vs stacked gradient dispatch.
use elastic_gossip::runtime::{BatchX, BatchXOwned, GradEngine, HloEngine};

fn main() {
    let w = 4usize;
    let mut e = HloEngine::load_for_workers("artifacts", "mlp_paper", 32, w).unwrap();
    let params: Vec<Vec<f32>> = vec![e.initial_params().unwrap(); w];
    let xs: Vec<BatchXOwned> = (0..w)
        .map(|k| BatchXOwned::F32((0..32 * 784).map(|i| ((i + k) % 97) as f32 * 0.01).collect()))
        .collect();
    let ys: Vec<Vec<i32>> = (0..w)
        .map(|k| (0..32).map(|i| ((i + k) % 10) as i32).collect())
        .collect();
    let seeds: Vec<i32> = (0..w as i32).collect();
    let mut grads = vec![vec![0.0f32; e.flat_size()]; w];

    // looped (per-worker artifact)
    for rep in 0..2 {
        let t = std::time::Instant::now();
        let n = 10;
        for _ in 0..n {
            for i in 0..w {
                e.loss_and_grad(&params[i], xs[i].as_ref(), &ys[i], seeds[i], &mut grads[i]).unwrap();
            }
        }
        println!("looped  rep{rep}: {:.1} ms/step (4 workers)", t.elapsed().as_secs_f64() * 1e3 / n as f64);
    }
    // stacked
    for rep in 0..2 {
        let t = std::time::Instant::now();
        let n = 10;
        for _ in 0..n {
            e.loss_and_grad_all(&params, &xs, &ys, &seeds, &mut grads).unwrap();
        }
        println!("stacked rep{rep}: {:.1} ms/step (4 workers)", t.elapsed().as_secs_f64() * 1e3 / n as f64);
    }
}
