//! In-process perf probe.
//!
//! With HLO artifacts present (`make artifacts`): per-worker vs stacked
//! gradient dispatch.  Without artifacts (CI / fresh checkout): a
//! comm-round probe at the paper's MLP size, so `just perf-smoke` always
//! exercises the hot path.
use elastic_gossip::algos::{CommCtx, ScratchArena};
use elastic_gossip::algos::gossip::ElasticGossipStrategy;
use elastic_gossip::comm::{Fabric, LinkModel};
use elastic_gossip::prelude::*;
use elastic_gossip::runtime::{BatchXOwned, GradEngine, HloEngine};

fn main() {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        grad_dispatch_probe();
    } else {
        println!("no artifacts/ — running the comm-round probe instead");
        comm_round_probe();
    }
}

/// Per-worker vs stacked gradient dispatch (needs HLO artifacts).
fn grad_dispatch_probe() {
    let w = 4usize;
    let mut e = HloEngine::load_for_workers("artifacts", "mlp_paper", 32, w).unwrap();
    let params: Vec<Vec<f32>> = vec![e.initial_params().unwrap(); w];
    let xs: Vec<BatchXOwned> = (0..w)
        .map(|k| BatchXOwned::F32((0..32 * 784).map(|i| ((i + k) % 97) as f32 * 0.01).collect()))
        .collect();
    let ys: Vec<Vec<i32>> = (0..w)
        .map(|k| (0..32).map(|i| ((i + k) % 10) as i32).collect())
        .collect();
    let seeds: Vec<i32> = (0..w as i32).collect();
    let mut grads = vec![vec![0.0f32; e.flat_size()]; w];

    // looped (per-worker artifact)
    for rep in 0..2 {
        let t = std::time::Instant::now();
        let n = 10;
        for _ in 0..n {
            for i in 0..w {
                e.loss_and_grad(&params[i], xs[i].as_ref(), &ys[i], seeds[i], &mut grads[i]).unwrap();
            }
        }
        println!("looped  rep{rep}: {:.1} ms/step (4 workers)", t.elapsed().as_secs_f64() * 1e3 / n as f64);
    }
    // stacked
    for rep in 0..2 {
        let t = std::time::Instant::now();
        let n = 10;
        for _ in 0..n {
            e.loss_and_grad_all(&params, &xs, &ys, &seeds, &mut grads).unwrap();
        }
        println!("stacked rep{rep}: {:.1} ms/step (4 workers)", t.elapsed().as_secs_f64() * 1e3 / n as f64);
    }
}

/// Elastic-gossip comm round at the paper MLP flat size: rounds/s and a
/// zero-allocation sanity check on the scratch arena.
fn comm_round_probe() {
    let flat = 2_913_290usize;
    let w = 8usize;
    let mut params: Vec<Vec<f32>> = (0..w).map(|i| vec![i as f32 * 1e-3; flat]).collect();
    let mut grads: Vec<Vec<f32>> = vec![Vec::new(); w];
    let mut fabric = Fabric::new(w + 1, LinkModel::default());
    let mut arena = ScratchArena::new();
    arena.ensure(w, flat);
    let mut strategy = ElasticGossipStrategy::new(0.5);
    let mut rng = Rng::new(7);
    let comm = vec![true; w];

    // warm-up pins the arena's high-water mark
    for _ in 0..2 {
        let mut ctx = CommCtx {
            params: &mut params,
            grads: &mut grads,
            fabric: &mut fabric,
            topology: &Topology::Full,
            step: 0,
            communicating: &comm,
            arena: &mut arena,
        };
        strategy.comm_round(&mut ctx, &mut rng).unwrap();
        fabric.end_round();
    }
    let fp = arena.footprint();

    let rounds = 20;
    let t = std::time::Instant::now();
    for _ in 0..rounds {
        let mut ctx = CommCtx {
            params: &mut params,
            grads: &mut grads,
            fabric: &mut fabric,
            topology: &Topology::Full,
            step: 0,
            communicating: &comm,
            arena: &mut arena,
        };
        strategy.comm_round(&mut ctx, &mut rng).unwrap();
        fabric.end_round();
    }
    let dt = t.elapsed().as_secs_f64();
    assert_eq!(arena.footprint(), fp, "comm round reallocated arena storage");
    println!(
        "elastic-gossip round, W={w} flat={flat}: {:.2} ms/round ({:.1} rounds/s), arena stable",
        dt * 1e3 / rounds as f64,
        rounds as f64 / dt
    );
}
