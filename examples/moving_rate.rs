//! The moving-rate study (§4.1.3 / Table 4.2, Figure 4.4): how the
//! elastic moving rate alpha shapes the explore-exploit tradeoff.
//!
//! Runs Elastic Gossip at alpha in {0.05, 0.25, 0.5, 0.75, 0.95} on the
//! compiled small MLP, at a moderate and a starved communication
//! probability — the paper's qualitative claims to reproduce:
//! alpha = 0.5 is a safe choice; extremes degrade, catastrophically so at
//! starved p (the paper's EG-8-0.0005-0.05 aggregate collapse to 0.43).
//!
//! ```bash
//! cargo run --release --example moving_rate
//! ```

use elastic_gossip::config::{CommSchedule, DatasetKind, EngineKind, ExperimentConfig};
use elastic_gossip::coordinator::run_experiment;
use elastic_gossip::metrics::write_curves_csv;
use elastic_gossip::prelude::*;

fn main() -> anyhow::Result<()> {
    let alphas = [0.05f32, 0.25, 0.5, 0.75, 0.95];
    let probs = [("p=0.0312", 0.03125f64), ("p=0.0005-starved", 0.0025)];

    println!("== Table 4.2 / Figure 4.4: effect of the moving rate alpha ==\n");
    let mut curves = Vec::new();
    for (pname, p) in probs {
        println!("{pname}:");
        println!("{:<8} {:>11} {:>11} {:>14}", "alpha", "rank0-acc", "agg-acc", "worker-spread");
        for alpha in alphas {
            let cfg = ExperimentConfig {
                label: format!("EG-{pname}-a{alpha:.2}"),
                method: Method::ElasticGossip { alpha },
                workers: 4,
                schedule: CommSchedule::Probability(p),
                engine: EngineKind::Hlo { model: "mlp_small".into() },
                dataset: DatasetKind::SyntheticVectors { dim: 64 },
                n_train: 4096,
                n_val: 512,
                n_test: 512,
                effective_batch: 32,
                epochs: 8,
                seed: 0,
                ..ExperimentConfig::default()
            };
            let report = run_experiment(&cfg)?;
            let spread = report
                .metrics
                .curve
                .last()
                .map(|pt| {
                    let (lo, hi) = pt.acc_range();
                    hi - lo
                })
                .unwrap_or(0.0);
            println!(
                "{:<8.2} {:>11.4} {:>11.4} {:>14.4}",
                alpha, report.rank0_accuracy, report.aggregate_accuracy, spread
            );
            curves.push(report.metrics.curve);
        }
        println!();
    }
    write_curves_csv("results/moving_rate", &curves)?;
    println!("curves written to results/moving_rate/ (Fig 4.4-style series)");
    println!("expected shape: mid-range alpha best; low alpha at starved p lets workers drift apart");
    Ok(())
}
