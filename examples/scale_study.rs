//! Fleet-scale study: the sharded virtual-clock event queue
//! (`shards:<n>` / `--shards`) pushed to 10⁵-node rosters.
//!
//! PR 7 shards the async runtime's event queue: nodes are pinned to
//! shards (`node % n`), each shard owns a local min-heap, gradient
//! compute fans out to one worker thread per shard, and the merged
//! (time, class, seq) pop order — hence the whole trajectory — is
//! bit-identical to the single-queue runtime.  This driver measures what
//! that buys and proves what it must not change:
//!
//! * **shard sweep** — one roster, `shards: 1/2/4`: events/sec, wall
//!   time, cross-shard message fraction, and the final-parameter digest
//!   (asserted identical across every shard count);
//! * **node sweep** — ring rosters from 10⁴ to 10⁵ nodes: events/sec
//!   and peak RSS, whose slope extrapolates the per-node footprint to
//!   10⁶ nodes;
//! * **spot checks** — churn + failure detection + link faults at
//!   `shards:1` vs `shards:4` (same digest, same event count), and
//!   message coalescing (`coalesce`) under the lockstep schedule (bit
//!   identical) vs real latency (cheaper simulated comm).
//!
//! ```bash
//! cargo run --release --example scale_study              # full study
//! cargo run --release --example scale_study -- --quick   # CI smoke
//! cargo run --release --example scale_study -- --bench   # + BENCH_scale.json
//! ```

use elastic_gossip::algos::Method;
use elastic_gossip::config::EngineKind;
use elastic_gossip::manifest::json::{self, Json, JsonObj};
use elastic_gossip::membership::{digest_params, ChurnSpec, FaultSpec, FdSpec};
use elastic_gossip::runtime::SyntheticSpec;
use elastic_gossip::runtime_async::{run_async, study_setup, AsyncRunReport, AsyncSimCfg};
use elastic_gossip::topology::Topology;

/// Peak resident set (VmHWM) in MB; 0.0 where /proc is unavailable.
/// Monotone over the process lifetime — size the biggest run last, or
/// read the delta between two probes.
fn peak_rss_mb() -> f64 {
    if let Ok(s) = std::fs::read_to_string("/proc/self/status") {
        for line in s.lines() {
            if let Some(rest) = line.strip_prefix("VmHWM:") {
                let kb: f64 = rest
                    .trim()
                    .trim_end_matches("kB")
                    .trim()
                    .parse()
                    .unwrap_or(0.0);
                return kb / 1024.0;
            }
        }
    }
    0.0
}

/// A scale-study configuration: ring topology, per-worker batch 1, two
/// steps per epoch — per-node state dominates, which is exactly what a
/// 10⁵–10⁶ node simulation has to keep cheap.
fn scale_cfg(
    w: usize,
    dim: usize,
    epochs: usize,
    shards: usize,
) -> (elastic_gossip::config::ExperimentConfig, SyntheticSpec) {
    let (mut cfg, _) = study_setup(Method::ElasticGossip { alpha: 0.5 }, w, 0.25, epochs, 11);
    cfg.engine = EngineKind::Synthetic { dim };
    cfg.topology = Topology::Ring;
    cfg.effective_batch = w; // per-worker batch 1
    cfg.n_train = 2 * w; // 2 steps per epoch
    cfg.n_val = 32;
    cfg.n_test = 32;
    cfg.shards = shards;
    cfg.label = format!("scale-w{w}-d{dim}-s{shards}");
    let spec = SyntheticSpec::for_cfg(&cfg).expect("synthetic engine");
    (cfg, spec)
}

struct Row {
    nodes: usize,
    shards: usize,
    dim: usize,
    events: u64,
    wall_s: f64,
    events_per_sec: f64,
    cross_shard_frac: f64,
    peak_rss_mb: f64,
    digest: u64,
}

fn run_row(w: usize, dim: usize, epochs: usize, shards: usize) -> Row {
    let (cfg, spec) = scale_cfg(w, dim, epochs, shards);
    let sim = AsyncSimCfg::straggler(w, 0.05, 0.1, 3.0);
    let t0 = std::time::Instant::now();
    let asy = run_async(&cfg, &spec, &sim).expect("scale run");
    let wall_s = t0.elapsed().as_secs_f64();
    let mut d: u64 = 0;
    for p in &asy.final_params {
        d ^= digest_params(p).rotate_left(17);
    }
    Row {
        nodes: w,
        shards,
        dim,
        events: asy.events,
        wall_s,
        events_per_sec: asy.events as f64 / wall_s.max(1e-9),
        cross_shard_frac: asy.cross_shard_frac,
        peak_rss_mb: peak_rss_mb(),
        digest: d,
    }
}

fn print_row(r: &Row) {
    println!(
        "{:>8} {:>7} {:>6} {:>10} {:>8.2} {:>12.0} {:>12.3} {:>10.1}",
        r.nodes, r.shards, r.dim, r.events, r.wall_s, r.events_per_sec, r.cross_shard_frac, r.peak_rss_mb
    );
}

/// Digest-level equality of two runs (bit-identity in aggregate form —
/// the proptests compare full vectors; at 10⁴ nodes a digest keeps the
/// study fast).
fn same_trajectory(a: &AsyncRunReport, b: &AsyncRunReport) -> bool {
    a.events == b.events
        && a.final_params.len() == b.final_params.len()
        && a
            .final_params
            .iter()
            .zip(b.final_params.iter())
            .all(|(x, y)| digest_params(x) == digest_params(y))
}

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let quick = argv.iter().any(|a| a == "--quick");
    let bench = argv.iter().any(|a| a == "--bench");
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let header = "   nodes  shards    dim     events   wall-s   events/sec  cross-shard    rss-MB";

    println!("== sharded event queue at fleet scale ({cores} host cores) ==\n");
    let mut rows: Vec<Row> = Vec::new();

    // --- shard sweep: same roster, more shards ---------------------------
    // heavy per-step compute (dim 4096) so the gradient fan-out — the
    // only parallel work — dominates; the trajectory digest must not move
    let (sweep_w, sweep_dim, sweep_epochs) = if quick { (10_000, 64, 1) } else { (4_096, 4_096, 2) };
    let shard_counts: &[usize] = if quick { &[2] } else { &[1, 2, 4] };
    println!("shard sweep: W={sweep_w}, ring, dim={sweep_dim}");
    println!("{header}");
    let mut sweep_digest: Option<u64> = None;
    for &s in shard_counts {
        let r = run_row(sweep_w, sweep_dim, sweep_epochs, s);
        print_row(&r);
        if let Some(d) = sweep_digest {
            assert_eq!(d, r.digest, "shards:{s} changed the trajectory");
        }
        sweep_digest = Some(r.digest);
        assert!(
            (s == 1) == (r.cross_shard_frac == 0.0),
            "cross-shard fraction must be 0 exactly for shards:1"
        );
        rows.push(r);
    }

    // --- node sweep: 10^4 -> 10^5 nodes ----------------------------------
    // small model (dim 64): per-node bookkeeping, not parameters, is the
    // scaling question.  RSS slope between the two rosters estimates the
    // marginal bytes/node, which is what extrapolates to 10^6.
    if !quick {
        println!("\nnode sweep: ring, dim=64, shards={}", cores.min(4));
        println!("{header}");
        let mut sweep: Vec<Row> = Vec::new();
        for &w in &[10_000usize, 100_000] {
            let r = run_row(w, 64, 1, cores.min(4));
            print_row(&r);
            sweep.push(r);
        }
        let (a, b) = (&sweep[0], &sweep[1]);
        let per_node = (b.peak_rss_mb - a.peak_rss_mb).max(0.0) * 1024.0 * 1024.0
            / (b.nodes - a.nodes) as f64;
        println!(
            "marginal footprint ≈ {:.0} bytes/node -> ~{:.1} GB at 10^6 nodes",
            per_node,
            (per_node * 1e6) / (1024.0 * 1024.0 * 1024.0)
        );
        rows.extend(sweep);
    }

    // --- spot check: churn + fd + faults, shards:1 vs shards:4 -----------
    let w = if quick { 256 } else { 512 };
    let mk = |shards: usize| {
        let (mut cfg, _) = scale_cfg(w, 64, 2, shards);
        // ring geometry is fixed at W slots, so elasticity here is
        // crash/rejoin (fresh joins need the full topology)
        cfg.churn = ChurnSpec::parse("crash@30%:5,rejoin@70%:5,crash@60%:9").expect("churn");
        cfg.fd = FdSpec::parse("fd:0.1:0.12:0.4:2").expect("fd");
        cfg.faults = FaultSpec::parse("drop:0.02,jitter:0.2,seed:3").expect("faults");
        let spec = SyntheticSpec::for_cfg(&cfg).expect("spec");
        let sim = AsyncSimCfg::straggler(w, 0.05, 0.1, 3.0);
        run_async(&cfg, &spec, &sim).expect("spot run")
    };
    let one = mk(1);
    let four = mk(4);
    assert!(
        same_trajectory(&one, &four),
        "churn+fd+faults trajectory diverged between shards:1 and shards:4"
    );
    println!(
        "\nspot check: W={w} churn+fd+faults — shards:1 == shards:4 \
         ({} events, {} survivors)",
        one.events,
        one.membership.final_alive.len()
    );

    // --- spot check: coalescing ------------------------------------------
    // lockstep (zero link): coalescing must be bit-identical; straggler
    // latency: frames pay the per-transfer latency once, so the simulated
    // comm clock comes down while raw/wire byte ledgers stay equal
    let (base_cfg, spec) = scale_cfg(w, 64, 2, 1);
    let mut co_cfg = base_cfg.clone();
    co_cfg.coalesce = true;
    let lock = AsyncSimCfg::lockstep(w);
    let a = run_async(&base_cfg, &spec, &lock).expect("lockstep");
    let b = run_async(&co_cfg, &spec, &lock).expect("lockstep coalesce");
    assert!(same_trajectory(&a, &b), "lockstep coalescing changed the trajectory");
    let sim = AsyncSimCfg::straggler(w, 0.05, 0.1, 3.0);
    let c = run_async(&base_cfg, &spec, &sim).expect("latency");
    let d = run_async(&co_cfg, &spec, &sim).expect("latency coalesce");
    assert_eq!(
        c.report.metrics.comm_bytes, d.report.metrics.comm_bytes,
        "coalescing must not change the raw byte ledger"
    );
    println!(
        "coalesce: lockstep bit-identical; under latency comm clock {:.3}s -> {:.3}s \
         at equal {} raw bytes",
        c.report.metrics.simulated_comm_s,
        d.report.metrics.simulated_comm_s,
        c.report.metrics.comm_bytes
    );

    // --- artifact ---------------------------------------------------------
    if bench {
        let mut root = JsonObj::new();
        root.insert("bench", Json::Str("scale".into()));
        root.insert("host_cores", Json::Num(cores as f64));
        let mut arr: Vec<Json> = Vec::new();
        for r in &rows {
            let mut o = JsonObj::new();
            o.insert("nodes", Json::Num(r.nodes as f64));
            o.insert("shards", Json::Num(r.shards as f64));
            o.insert("topology", Json::Str("ring".into()));
            o.insert("dim", Json::Num(r.dim as f64));
            o.insert("events", Json::Num(r.events as f64));
            o.insert("wall_s", Json::Num(r.wall_s));
            o.insert("events_per_sec", Json::Num(r.events_per_sec));
            o.insert("cross_shard_frac", Json::Num(r.cross_shard_frac));
            o.insert("peak_rss_mb", Json::Num(r.peak_rss_mb));
            arr.push(Json::Obj(o));
        }
        root.insert("runs", Json::Arr(arr));
        let path = "BENCH_scale.json";
        match std::fs::write(path, json::write(&Json::Obj(root))) {
            Ok(()) => println!("\nwrote {path}"),
            Err(e) => eprintln!("\ncould not write {path}: {e}"),
        }
    }

    println!(
        "\nreading: the sharded queue keeps every trajectory bit-identical\n\
         (the digests above are asserted, not eyeballed) while gradient\n\
         compute rides one thread per shard — events/sec scales with\n\
         shards wherever per-step compute dominates, and the marginal\n\
         footprint stays flat enough to extrapolate a 10^6-node roster\n\
         onto one machine."
    );
}
