//! Controlled-asynchrony study on the *real* event-driven runtime: train
//! Elastic Gossip under straggler scenarios with actual gradients and
//! message passing, and report accuracy/loss **and** measured staleness —
//! the experiment the thesis's future-work chapter asks for ("studying
//! the effects of asynchrony that is controlled in a simulated
//! environment"), end to end.
//!
//! For each scenario the same experiment runs two ways:
//!
//! * the synchronous barriered coordinator (the thesis's setting) — its
//!   accuracy is the quality reference, and the time-only simulator
//!   prices its barrier under the scenario's speeds;
//! * the event-driven asynchronous runtime under the same speeds — full
//!   self-utilization, at the price of stale exchanges whose
//!   distribution the staleness histogram quantifies.
//!
//! With `--codec q8` or `--codec topk:<frac>` the exchanges travel
//! through a lossy wire codec (`comm::codec`) — the bandwidth-constrained
//! variant of the same study: the table gains encoded bytes-on-wire next
//! to the raw payload traffic.
//!
//! ```bash
//! cargo run --release --example async_straggler          # real training
//! cargo run --release --example async_straggler -- --codec topk:0.01
//! cargo run --release --example async_straggler -- --dry # time-only replay
//! ```

use elastic_gossip::algos::Method;
use elastic_gossip::comm::codec::CodecKind;
use elastic_gossip::comm::LinkModel;
use elastic_gossip::coordinator::run_experiment;
use elastic_gossip::runtime_async::{run_async, study_setup, AsyncSimCfg};
use elastic_gossip::sim::{simulate_asynchronous, simulate_synchronous, WorkerSpeed};

/// The original time-only replay (no training) — kept as `--dry`.
fn dry_run() {
    let steps = 4000u64;
    println!("== controlled asynchrony (time-only replay): barrier cost vs gossip staleness ==\n");
    println!(
        "{:<34} {:>10} {:>12} {:>12} {:>12}",
        "scenario", "virtual-s", "self-util", "async-util", "staleness"
    );
    for (name, w, slow) in [
        ("8 homogeneous", 8usize, 1.0f64),
        ("8 with 1 straggler x2", 8, 2.0),
        ("8 with 1 straggler x4", 8, 4.0),
        ("16 with 2 stragglers x4", 16, 4.0),
    ] {
        let mut speeds: Vec<WorkerSpeed> = (0..w).map(|_| WorkerSpeed::uniform(0.05)).collect();
        speeds[w - 1].slow_factor = slow;
        if w >= 16 {
            speeds[w - 2].slow_factor = slow;
        }
        let sync = simulate_synchronous(&speeds, steps, 12 * 4 * 2_913_290 / 10, LinkModel::default(), 11);
        let asy = simulate_asynchronous(&speeds, steps, 0.03125, 11);
        println!(
            "{:<34} {:>10.1} {:>12.3} {:>12.3} {:>12.2}",
            name,
            sync.total_s,
            sync.mean_self_utilization(),
            asy.mean_self_utilization(),
            asy.mean_async_staleness
        );
    }
    println!(
        "\nreading: synchronous utilization collapses as stragglers appear (the\n\
         §2.1.2 motivation for asynchrony); the async variant stays ~fully\n\
         utilized at the price of stale gossip exchanges — the controlled\n\
         tradeoff the thesis proposes studying."
    );
}

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    if argv.iter().any(|a| a == "--dry") {
        dry_run();
        return;
    }
    let codec = match argv.iter().position(|a| a == "--codec") {
        Some(i) => {
            let v = argv.get(i + 1).expect("--codec needs a value");
            CodecKind::parse(v).expect("bad --codec value")
        }
        None => CodecKind::Identity,
    };

    let w = 8usize;
    let (mut cfg, spec) = study_setup(Method::ElasticGossip { alpha: 0.5 }, w, 0.125, 6, 7);
    cfg.codec = codec;

    // quality reference: the synchronous barriered run (identical
    // trajectory regardless of speeds — that is the point of barriers;
    // it always ships raw snapshots, so the codec stays on the async side)
    let sync_cfg = elastic_gossip::config::ExperimentConfig {
        codec: CodecKind::Identity,
        ..cfg.clone()
    };
    let sync = run_experiment(&sync_cfg).expect("sync run");
    println!(
        "== event-driven async gossip vs the synchronous barrier (real training, codec {}) ==\n",
        codec.label()
    );
    println!(
        "sync reference: rank0 {:.4}  aggregate {:.4}  final train-loss {:.4}\n",
        sync.rank0_accuracy,
        sync.aggregate_accuracy,
        sync.metrics.curve.points.last().unwrap().train_loss
    );
    println!(
        "{:<24} {:>8} {:>8} {:>10} {:>10} {:>10} {:>11} {:>11} {:>10}",
        "scenario", "rank0", "agg", "loss", "stale-avg", "stale-max", "util-async", "util-sync", "wire-MB"
    );

    for (name, slow) in [
        ("homogeneous", 1.0f64),
        ("1 straggler x2", 2.0),
        ("1 straggler x4", 4.0),
    ] {
        let sim = AsyncSimCfg::straggler(w, 0.05, 0.1, slow);
        let asy = run_async(&cfg, &spec, &sim).expect("async run");
        // what the same speeds would cost the barriered run (per-round
        // traffic ~ the async run's bytes over its steps)
        let bytes_per_round = asy.report.metrics.comm_bytes / cfg.total_steps().max(1);
        let sync_sim = simulate_synchronous(
            &sim.speeds,
            cfg.total_steps(),
            bytes_per_round,
            sim.link,
            sim.speed_seed,
        );
        println!(
            "{:<24} {:>8.4} {:>8.4} {:>10.4} {:>10.2} {:>10} {:>11.3} {:>11.3} {:>10.3}",
            name,
            asy.report.rank0_accuracy,
            asy.report.aggregate_accuracy,
            asy.report.metrics.curve.points.last().unwrap().train_loss,
            asy.staleness.mean(),
            asy.staleness.max(),
            asy.mean_self_utilization(),
            sync_sim.mean_self_utilization(),
            asy.report.metrics.wire_bytes as f64 / 1e6,
        );
    }

    println!(
        "\nreading: the barrier run's utilization collapses toward 1/slow-factor\n\
         as a straggler appears, while the event-driven nodes stay ~fully\n\
         busy; the cost is visible in the staleness columns — exchanges\n\
         apply parameters that are measurably behind the receiver, yet the\n\
         gossip average still tracks the synchronous reference's accuracy.\n\
         (§2.1.2's asynchrony argument, reproduced with real training.)"
    );
}
