//! Controlled-asynchrony study (the thesis's future-work chapter,
//! implemented as an extension): quantify what synchronous barriers cost
//! under stragglers, and what staleness an asynchronous variant of
//! Elastic Gossip would see — without any hardware noise, exactly the
//! "simulated (controlled) asynchrony" environment the thesis calls for.
//!
//! ```bash
//! cargo run --release --example async_straggler
//! ```

use elastic_gossip::comm::LinkModel;
use elastic_gossip::sim::{simulate_asynchronous, simulate_synchronous, WorkerSpeed};

fn main() {
    let steps = 4000u64;
    println!("== controlled asynchrony: barrier cost vs gossip staleness ==\n");
    println!(
        "{:<34} {:>10} {:>12} {:>12} {:>12}",
        "scenario", "virtual-s", "self-util", "async-util", "staleness"
    );
    for (name, w, slow) in [
        ("8 homogeneous", 8usize, 1.0f64),
        ("8 with 1 straggler x2", 8, 2.0),
        ("8 with 1 straggler x4", 8, 4.0),
        ("16 with 2 stragglers x4", 16, 4.0),
    ] {
        let mut speeds: Vec<WorkerSpeed> = (0..w).map(|_| WorkerSpeed::uniform(0.05)).collect();
        speeds[w - 1].slow_factor = slow;
        if w >= 16 {
            speeds[w - 2].slow_factor = slow;
        }
        let sync = simulate_synchronous(&speeds, steps, 12 * 4 * 2_913_290 / 10, LinkModel::default(), 11);
        let asy = simulate_asynchronous(&speeds, steps, 0.03125, 11);
        println!(
            "{:<34} {:>10.1} {:>12.3} {:>12.3} {:>12.2}",
            name,
            sync.total_s,
            sync.mean_self_utilization(),
            asy.mean_self_utilization(),
            asy.mean_async_staleness
        );
    }
    println!(
        "\nreading: synchronous utilization collapses as stragglers appear (the\n\
         §2.1.2 motivation for asynchrony); the async variant stays ~fully\n\
         utilized at the price of stale gossip exchanges — the controlled\n\
         tradeoff the thesis proposes studying."
    );
}
