//! Elastic-membership study: the paper-style experiment under dynamic
//! join/leave/crash, end to end.
//!
//! The thesis motivates gossip training with heterogeneous deployments —
//! "training at data sources such as IoT devices and edge servers" —
//! where workers vanish and return mid-run.  This driver measures what
//! that costs: the acceptance schedule crashes two of eight nodes
//! mid-run and rejoins one (restored from its epoch-boundary
//! checkpoint), for every pairwise gossip method under the identity, q8
//! and top-k wire codecs.  The table reports survivor count and
//! accuracy, the dropped-traffic ledger, the Elastic Gossip rollback
//! count, and GoSGD's push-sum mass — which must come back to exactly 1
//! through arbitrary churn (the hard invariant, property-tested in
//! `rust/tests/proptests.rs`).
//!
//! ```bash
//! cargo run --release --example churn_study
//! cargo run --release --example churn_study -- --quick     # CI smoke
//! cargo run --release --example churn_study -- --churn rand:3:1:42
//! ```
//!
//! The final section demonstrates the crash-recovery plumbing itself:
//! the run's per-node async checkpoint is written to disk
//! (`coordinator::checkpoint::AsyncCheckpoint`), reloaded, and verified
//! against the in-memory mirror.

use elastic_gossip::algos::Method;
use elastic_gossip::comm::codec::CodecKind;
use elastic_gossip::membership::ChurnSpec;
use elastic_gossip::runtime_async::{run_async, study_setup, AsyncSimCfg};

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let quick = argv.iter().any(|a| a == "--quick");
    let churn_spec = match argv.iter().position(|a| a == "--churn") {
        Some(i) => argv.get(i + 1).expect("--churn needs a value").clone(),
        None => elastic_gossip::membership::STANDARD_CHURN.to_string(),
    };
    let churn = ChurnSpec::parse(&churn_spec).expect("bad --churn spec");

    let w = 8usize;
    let epochs = if quick { 4 } else { 10 };
    println!("== elastic membership: {w} workers under `{}` ==\n", churn.label());
    println!(
        "{:<10} {:<10} {:>6} {:>8} {:>8} {:>10} {:>9} {:>11} {:>9} {:>12}",
        "method", "codec", "alive", "rank0", "agg", "loss", "dropped", "dropped-kB", "rollback", "mass"
    );

    let codecs: Vec<CodecKind> = if quick {
        vec![CodecKind::Identity]
    } else {
        vec![
            CodecKind::Identity,
            CodecKind::Q8 { chunk: 4096 },
            CodecKind::TopK { frac: 0.25 },
        ]
    };
    let mut last_ckpt = None;
    let mut last_label = String::new();
    for method in [
        Method::ElasticGossip { alpha: 0.5 },
        Method::GossipingSgdPull,
        Method::GossipingSgdPush,
        Method::GoSgd,
    ] {
        for codec in &codecs {
            let (mut cfg, spec) = study_setup(method.clone(), w, 0.125, epochs, 7);
            cfg.codec = *codec;
            cfg.churn = churn.clone();
            cfg.label = format!("churn-{}-{}", method.short_label(), codec.label());
            let sim = AsyncSimCfg::straggler(w, 0.05, 0.1, 3.0);
            let asy = run_async(&cfg, &spec, &sim).expect("churn run");
            let m = &asy.report.metrics;
            println!(
                "{:<10} {:<10} {:>6} {:>8.4} {:>8.4} {:>10.4} {:>9} {:>11.2} {:>9} {:>12}",
                method.short_label(),
                codec.label(),
                asy.membership.final_alive.len(),
                asy.report.rank0_accuracy,
                asy.report.aggregate_accuracy,
                m.curve.points.last().map(|p| p.train_loss).unwrap_or(f32::NAN),
                m.dropped_messages,
                m.dropped_bytes as f64 / 1e3,
                asy.membership.rolled_back_msgs,
                asy.push_sum_mass
                    .map(|x| format!("{x:.9}"))
                    .unwrap_or_else(|| "-".into()),
            );
            if let Some(mass) = asy.push_sum_mass {
                assert!(
                    (mass - 1.0).abs() < 1e-9,
                    "push-sum mass must survive churn exactly, got {mass}"
                );
            }
            last_label = cfg.label.clone();
            last_ckpt = asy.checkpoint;
        }
    }

    // crash-recovery plumbing, demonstrated on the last run: persist the
    // per-node async checkpoint, reload it, verify it round-trips
    if let Some(ckpt) = last_ckpt {
        let dir = std::env::temp_dir().join(format!("eg-churn-ckpt-{}", std::process::id()));
        ckpt.save(&dir).expect("saving async checkpoint");
        let back = elastic_gossip::coordinator::checkpoint::AsyncCheckpoint::load(&dir)
            .expect("reloading async checkpoint");
        assert_eq!(back, ckpt, "async checkpoint must round-trip bit-for-bit");
        let present = ckpt.nodes.iter().filter(|n| n.is_some()).count();
        println!(
            "\ncheckpoint: {present}/{} node snapshots for {last_label} round-tripped via {}",
            ckpt.nodes.len(),
            dir.display()
        );
    }

    println!(
        "\nreading: gossip training degrades gracefully under churn — the\n\
         survivors' accuracy tracks the fixed-roster run, undeliverable\n\
         traffic lands in the dropped ledger instead of corrupting state,\n\
         rejoiners bootstrap from a live peer's exact parameters, and\n\
         GoSGD's push-sum mass is exactly 1 at termination no matter how\n\
         many nodes came and went (the invariant a barriered All-reduce\n\
         cannot even define)."
    );
}
