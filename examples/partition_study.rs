//! Lossy-link partition study: gossip-native failure detection under
//! dropped messages, delay jitter and a transient network partition.
//!
//! PR 5 gave the runtime dynamic membership, but every node read death
//! from the simulation oracle.  This driver turns the oracle off (`fd:`
//! on): nodes learn the roster the SWIM way — periodic ping / ping-req
//! probes, alive -> suspect -> confirmed-dead with incarnation-stamped
//! refutations, and membership rumors piggybacked on every gossip
//! payload.  The link fault plane (`faults:` grammar) supplies the
//! adversary: seeded per-link drop probability, delay jitter, and a
//! scheduled partition that severs a node cut mid-run.
//!
//! The table reports, per method and loss rate: survivor count and
//! accuracy, probe/ack traffic, suspicion and *false*-suspicion counts,
//! the mean detection latency for real crashes, and the terminal
//! push-sum mass for GoSGD (exactly 1, detector or not).
//!
//! ```bash
//! cargo run --release --example partition_study
//! cargo run --release --example partition_study -- --quick    # CI smoke
//! ```

use elastic_gossip::algos::Method;
use elastic_gossip::membership::{ChurnSpec, FaultSpec, FdSpec};
use elastic_gossip::runtime_async::{run_async, study_setup, AsyncSimCfg};

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let quick = argv.iter().any(|a| a == "--quick");

    let w = 8usize;
    let epochs = if quick { 4 } else { 8 };
    // two crashes mid-run; detection (not the oracle) must find them
    let churn = ChurnSpec::parse("crash@30%:5,crash@45%:6").expect("churn spec");
    let fd = FdSpec::parse("fd:0.1:0.12:0.4:2").expect("fd spec");
    let loss_rates: &[f64] = if quick { &[0.0, 0.05] } else { &[0.0, 0.02, 0.05, 0.10] };

    println!(
        "== gossip-native failure detection: {w} workers, `{}`, fd `{}` ==\n",
        churn.label(),
        fd.label()
    );
    println!(
        "{:<10} {:>6} {:>6} {:>8} {:>8} {:>8} {:>7} {:>7} {:>9} {:>9} {:>12}",
        "method", "drop%", "alive", "rank0", "agg", "probes", "susp", "false", "confirms", "det-lat", "mass"
    );

    for method in [
        Method::ElasticGossip { alpha: 0.5 },
        Method::GossipingSgdPull,
        Method::GossipingSgdPush,
        Method::GoSgd,
    ] {
        for &drop in loss_rates {
            let (mut cfg, spec) = study_setup(method.clone(), w, 0.125, epochs, 7);
            cfg.churn = churn.clone();
            cfg.fd = fd.clone();
            // mid-run partition: the cut {0,1} | {2..} is severed for a
            // slice of the run on top of the uniform drop probability.
            // The window is kept just under the suspicion timeout so the
            // cut raises (false) suspicions that refutations then clear,
            // rather than letting both sides symmetrically confirm each
            // other dead.
            cfg.faults = FaultSpec::parse(&format!(
                "drop:{drop},jitter:0.3,partition@55%-58%:2,seed:11"
            ))
            .expect("faults spec");
            cfg.label = format!("fd-{}-drop{}", method.short_label(), drop);
            let sim = AsyncSimCfg::straggler(w, 0.05, 0.1, 3.0);
            let asy = run_async(&cfg, &spec, &sim).expect("fd run");
            let fdr = asy
                .membership
                .fd
                .as_ref()
                .expect("fd-enabled runs attach an FdReport");
            println!(
                "{:<10} {:>6} {:>6} {:>8.4} {:>8.4} {:>8} {:>7} {:>7} {:>9} {:>9} {:>12}",
                method.short_label(),
                format!("{:.0}", drop * 100.0),
                asy.membership.final_alive.len(),
                asy.report.rank0_accuracy,
                asy.report.aggregate_accuracy,
                fdr.probes,
                fdr.suspicions,
                fdr.false_suspicions,
                fdr.confirms,
                if fdr.detection.count() > 0 {
                    format!("{:.2}s", fdr.detection.mean())
                } else {
                    "-".into()
                },
                asy.push_sum_mass
                    .map(|x| format!("{x:.9}"))
                    .unwrap_or_else(|| "-".into()),
            );
            // the invariants the table is demonstrating, enforced
            assert_eq!(
                asy.membership.final_alive.len(),
                6,
                "{method:?} drop={drop}: survivors must converge to 6"
            );
            assert!(
                fdr.detection.count() > 0,
                "{method:?} drop={drop}: neither crash was ever detected"
            );
            if let Some(mass) = asy.push_sum_mass {
                assert!(
                    (mass - 1.0).abs() < 1e-9,
                    "push-sum mass must survive detection exactly, got {mass}"
                );
            }
        }
    }

    println!(
        "\nreading: the detector replaces the oracle without replacing the\n\
         physics — real crashes are confirmed within a few probe periods\n\
         (detection latency above), link loss inflates suspicion counts\n\
         but incarnation-stamped refutations keep false suspicions from\n\
         killing live nodes, a transient partition heals instead of\n\
         splitting the roster, and the conserved-state invariants (push-sum\n\
         mass exactly 1) hold with membership now a *belief*, not a fact."
    );
}
