"""Build-time compile path (Layer 1 + Layer 2).

This package is *never* imported at training time.  ``make artifacts``
runs :mod:`compile.aot` once to lower every model/kernel to HLO text under
``artifacts/``; the rust coordinator then loads those files via the PJRT C
API and python leaves the picture entirely.
"""
