"""Elastic pair update (Eqs. 3.7 / 3.8) as a fused Pallas kernel.

The communication-related component of Elastic Gossip, applied when worker
*i* gossips with peer *k*:

    delta   = alpha * (theta_i - theta_k)
    theta_i' = theta_i - delta
    theta_k' = theta_k + delta

The two updates are *elastically symmetric*: ``theta_i' + theta_k' ==
theta_i + theta_k`` exactly (the same ``delta`` is subtracted and added),
which is the invariant the thesis argues is crucial for stability.  The
kernel computes ``delta`` once and emits both outputs, so exactly the
quantity that leaves *i* enters *k* and the pairwise sum is conserved to
one f32 rounding per add (two independent passes could compute different
deltas and break even that).

Operates on the *flat* parameter vector (the rust coordinator keeps each
worker's parameters as one contiguous f32 buffer).  The flat vector is
reshaped to ``(rows, 128)`` lanes and tiled in ``(block_rows, 128)``
blocks — the natural VPU layout on TPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 256  # (256, 128) f32 tile = 128 KiB per operand


def _pair_kernel(alpha_ref, ti_ref, tk_ref, oi_ref, ok_ref):
    alpha = alpha_ref[0]
    delta = alpha * (ti_ref[...] - tk_ref[...])
    oi_ref[...] = ti_ref[...] - delta
    ok_ref[...] = tk_ref[...] + delta


def elastic_pair_update(
    theta_i: jax.Array,
    theta_k: jax.Array,
    alpha: jax.Array,
    *,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Apply the symmetric elastic update to a pair of flat parameter vectors.

    ``theta_i``, ``theta_k``: shape ``(n,)`` f32; ``alpha``: scalar or
    ``(1,)`` f32 (runtime-variable so one artifact serves every moving
    rate).  Returns ``(theta_i', theta_k')``.
    """
    assert theta_i.shape == theta_k.shape and theta_i.ndim == 1
    n = theta_i.shape[0]
    alpha = jnp.asarray(alpha, jnp.float32).reshape(1)

    rows = -(-n // LANES)
    padded = rows * LANES
    block_rows = min(BLOCK_ROWS, rows)
    grid_rows = -(-rows // block_rows)
    rows_p = grid_rows * block_rows

    def prep(t):
        return jnp.pad(t, (0, rows_p * LANES - n)).reshape(rows_p, LANES)

    del padded
    oi, ok = pl.pallas_call(
        _pair_kernel,
        grid=(grid_rows,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows_p, LANES), theta_i.dtype),
            jax.ShapeDtypeStruct((rows_p, LANES), theta_i.dtype),
        ],
        interpret=interpret,
    )(alpha, prep(theta_i), prep(theta_k))
    return oi.reshape(-1)[:n], ok.reshape(-1)[:n]
