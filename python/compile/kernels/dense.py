"""Fused dense (matmul + bias + optional ReLU) Pallas kernels.

This is the compute hot-spot of the paper's MNIST MLP (three 1024-wide
dense layers account for >99% of the FLOPs of a training step), so it is
the Layer-1 kernel of this reproduction.

TPU-idiomatic tiling, lowered with ``interpret=True``:

* the grid is ``(M/bm, N/bn)``; each program instance owns one ``(bm, bn)``
  output tile, reading a ``(bm, K)`` strip of ``x`` and a ``(K, bn)`` strip
  of ``w``.  For the paper's layer shapes (K <= 1024) a full-K strip fits
  comfortably in VMEM: with ``bm = bn = 128`` the working set is
  ``128*1024*4 + 1024*128*4 + 128*128*4 ~= 1.1 MiB`` out of ~16 MiB VMEM,
  leaving room for double buffering.
* tile sizes are multiples of (8, 128) to map onto the VPU lanes and feed
  the 128x128 MXU with bf16/f32 operands; accumulation stays in f32.
* arbitrary shapes are handled by padding to tile multiples in the wrapper
  (zero rows/cols contribute zeros to the accumulator, bias is applied
  inside the kernel so padded columns stay exact).

The backward pass is expressed with the same ``matmul`` kernel via a
``jax.custom_vjp`` so that ``jax.grad`` through a model built on
:func:`dense` lowers the *backward* matmuls through Pallas too.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: multiples of the (8, 128) VPU lane shape; 128x128
# feeds the MXU systolic array exactly.  On the CPU-PJRT target the grid
# lowers to a sequential while-loop (one dynamic-slice + dot per tile),
# which defeats XLA:CPU's threaded single-dot path — so `make artifacts`
# exports with large blocks (EG_PALLAS_BLOCK_{M,N}, see EXPERIMENTS.md
# §Perf), collapsing the grid to ~1 tile per layer while keeping the same
# kernel code.  The TPU tiling analysis in DESIGN.md uses the 128x128
# defaults.
BLOCK_M = int(os.environ.get("EG_PALLAS_BLOCK_M", "128"))
BLOCK_N = int(os.environ.get("EG_PALLAS_BLOCK_N", "128"))


def _pick_block(dim: int, preferred: int) -> int:
    """Largest power-of-two tile <= preferred that does not over-pad dim."""
    b = preferred
    while b > 8 and b >= 2 * dim:
        b //= 2
    return b


def _pad_to(x: jax.Array, rows: int, cols: int) -> jax.Array:
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


def _ceil_to(n: int, b: int) -> int:
    return (n + b - 1) // b * b


# ---------------------------------------------------------------------------
# plain blocked matmul
# ---------------------------------------------------------------------------


def _matmul_kernel(x_ref, w_ref, o_ref):
    # One (bm, bn) output tile: full-K contraction, f32 accumulation on the
    # MXU (preferred_element_type pins the accumulator dtype).
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    block_m: int = BLOCK_M,
    block_n: int = BLOCK_N,
    interpret: bool = True,
) -> jax.Array:
    """``x @ w`` as a blocked Pallas kernel. ``x: (M, K)``, ``w: (K, N)``."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {w.shape}"
    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    mp, np_ = _ceil_to(m, bm), _ceil_to(n, bn)
    xp = _pad_to(x, mp, k)
    wp = _pad_to(w, k, np_)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# fused dense: x @ w + b, optional ReLU
# ---------------------------------------------------------------------------


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, relu: bool):
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...]  # (1, bn) broadcasts over rows
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.astype(o_ref.dtype)


def _dense_fwd_impl(x, w, b, relu, block_m, block_n, interpret):
    m, k = x.shape
    _, n = w.shape
    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    mp, np_ = _ceil_to(m, bm), _ceil_to(n, bn)
    xp = _pad_to(x, mp, k)
    wp = _pad_to(w, k, np_)
    bp = jnp.pad(b, (0, np_ - n)).reshape(1, np_)
    out = pl.pallas_call(
        functools.partial(_dense_kernel, relu=relu),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=interpret,
    )(xp, wp, bp)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# elementwise ReLU-mask multiply (backward helper)
# ---------------------------------------------------------------------------


def _mask_kernel(dy_ref, out_ref, o_ref):
    o_ref[...] = dy_ref[...] * (out_ref[...] > 0.0).astype(dy_ref.dtype)


def relu_mask_mul(dy: jax.Array, out: jax.Array, *, interpret: bool = True) -> jax.Array:
    """``dy * (out > 0)`` — the ReLU backward gate, as a Pallas kernel."""
    m, n = dy.shape
    bm = _pick_block(m, BLOCK_M)
    bn = _pick_block(n, BLOCK_N)
    mp, np_ = _ceil_to(m, bm), _ceil_to(n, bn)
    res = pl.pallas_call(
        _mask_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), dy.dtype),
        interpret=interpret,
    )(_pad_to(dy, mp, np_), _pad_to(out, mp, np_))
    return res[:m, :n]


# ---------------------------------------------------------------------------
# custom_vjp wiring
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x: jax.Array, w: jax.Array, b: jax.Array, relu: bool = True) -> jax.Array:
    """Fused ``relu(x @ w + b)`` (or affine-only with ``relu=False``)."""
    return _dense_fwd_impl(x, w, b, relu, BLOCK_M, BLOCK_N, True)


def _dense_fwd(x, w, b, relu):
    out = _dense_fwd_impl(x, w, b, relu, BLOCK_M, BLOCK_N, True)
    # Save the *output* rather than the pre-activation: for ReLU,
    # (out > 0) == (pre > 0) except at exactly 0 where the subgradient is 0
    # either way; saves one VMEM-resident tensor.
    return out, (x, w, out)


def _dense_bwd(relu, res, dy):
    x, w, out = res
    dz = relu_mask_mul(dy, out) if relu else dy
    dx = matmul(dz, w.T)
    dw = matmul(x.T, dz)
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)


def vmem_footprint_bytes(k: int, block_m: int = BLOCK_M, block_n: int = BLOCK_N) -> int:
    """Estimated VMEM working set of one grid step of the fused dense kernel.

    Used by DESIGN.md / EXPERIMENTS.md §Perf to reason about real-TPU
    behaviour (interpret=True gives no hardware signal).
    """
    f32 = 4
    x_tile = block_m * k * f32
    w_tile = k * block_n * f32
    b_tile = block_n * f32
    o_tile = block_m * block_n * f32
    return 2 * (x_tile + w_tile + b_tile) + o_tile  # x2: double buffering
