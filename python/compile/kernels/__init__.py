"""Layer-1 Pallas kernels (build-time only).

Every kernel here is lowered with ``interpret=True`` so the resulting HLO
runs on any PJRT backend, including the CPU client used by the rust
coordinator.  Real-TPU performance is *estimated* (VMEM footprint + MXU
utilization arithmetic) in DESIGN.md / EXPERIMENTS.md §Perf.

Correctness oracle for every kernel lives in :mod:`compile.kernels.ref`
and is enforced by ``python/tests`` (pytest + hypothesis).
Import from the submodules directly (``from compile.kernels.dense import
dense``): the package intentionally re-exports nothing, since the kernel
entry points share names with their modules.
"""
