"""Pure-jnp oracles for every Layer-1 Pallas kernel.

These are the single source of truth for kernel correctness: pytest +
hypothesis sweep shapes/dtypes and ``assert_allclose`` the Pallas outputs
against these implementations.  Keep them boring and obviously right.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def dense_ref(x: jax.Array, w: jax.Array, b: jax.Array, relu: bool = True) -> jax.Array:
    out = jnp.dot(x, w, preferred_element_type=jnp.float32) + b
    if relu:
        out = jnp.maximum(out, 0.0)
    return out.astype(x.dtype)


def relu_mask_mul_ref(dy: jax.Array, out: jax.Array) -> jax.Array:
    return dy * (out > 0.0).astype(dy.dtype)


def elastic_pair_update_ref(theta_i, theta_k, alpha):
    delta = jnp.float32(alpha) * (theta_i - theta_k)
    return theta_i - delta, theta_k + delta


def nag_update_ref(theta, v, g, eta, mu):
    eta = jnp.float32(eta)
    mu = jnp.float32(mu)
    v_new = mu * v - eta * g
    theta_new = theta - eta * g + mu * v_new
    return theta_new, v_new


def dense_grads_ref(x, w, b, dy, relu: bool = True):
    """Reference VJP of dense(x, w, b) against upstream cotangent dy."""

    def f(x_, w_, b_):
        return dense_ref(x_, w_, b_, relu)

    _, vjp = jax.vjp(f, x, w, b)
    return vjp(dy)
