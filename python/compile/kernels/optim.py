"""Fused NAG (Nesterov) update — Algorithm 5 lines 3 & 9 — as a Pallas kernel.

    v'     = mu * v - eta * g          (velocity, line 3)
    theta' = theta - eta * g + mu * v' (parameter, line 9 — uses the NEW v)

One fused elementwise pass over the flat parameter vector instead of four
separate AXPYs; ``eta`` and ``mu`` are runtime inputs so a single artifact
serves every learning-rate schedule point.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 256


def _nag_kernel(hp_ref, theta_ref, v_ref, g_ref, ot_ref, ov_ref):
    eta = hp_ref[0]
    mu = hp_ref[1]
    v_new = mu * v_ref[...] - eta * g_ref[...]
    ov_ref[...] = v_new
    ot_ref[...] = theta_ref[...] - eta * g_ref[...] + mu * v_new


def nag_update(
    theta: jax.Array,
    v: jax.Array,
    g: jax.Array,
    eta: jax.Array,
    mu: jax.Array,
    *,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Fused NAG step over flat vectors; returns ``(theta', v')``."""
    assert theta.shape == v.shape == g.shape and theta.ndim == 1
    n = theta.shape[0]
    hp = jnp.stack(
        [jnp.asarray(eta, jnp.float32), jnp.asarray(mu, jnp.float32)]
    ).reshape(2)

    rows = -(-n // LANES)
    block_rows = min(BLOCK_ROWS, rows)
    grid_rows = -(-rows // block_rows)
    rows_p = grid_rows * block_rows

    def prep(t):
        return jnp.pad(t, (0, rows_p * LANES - n)).reshape(rows_p, LANES)

    ot, ov = pl.pallas_call(
        _nag_kernel,
        grid=(grid_rows,),
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows_p, LANES), theta.dtype),
            jax.ShapeDtypeStruct((rows_p, LANES), theta.dtype),
        ],
        interpret=interpret,
    )(hp, prep(theta), prep(v), prep(g))
    return ot.reshape(-1)[:n], ov.reshape(-1)[:n]
