"""AOT lowering: every model/kernel → HLO *text* + manifest.json.

Run once by ``make artifacts``::

    cd python && python -m compile.aot --out ../artifacts

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the rust ``xla`` crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/README.md.

``manifest.json`` records, for every artifact, the exact positional input
and output tensor specs (name/shape/dtype) so the rust runtime can pack
literals without guessing; plus per-model parameter layouts (the flat
f32 buffer segmentation the coordinator uses).

``fixtures.json`` records golden outputs of a few tiny artifacts on fixed
inputs; a rust integration test replays them through the PJRT path to
prove cross-language numerical agreement.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.gossip import elastic_pair_update
from .kernels.optim import nag_update

# (model -> train batch sizes, eval batch size).  Train batches cover the
# per-worker batches implied by the paper's effective batch 128:
# |W|=1 -> 128, |W|=4 -> 32, |W|=8 -> 16.
#
# STACKED_TRAIN additionally lowers a vmapped step over all W workers at
# once — one PJRT call per synchronized step instead of W, letting
# XLA:CPU batch the matmuls across replicas (EXPERIMENTS.md §Perf: ~3x).
TRAIN_BATCHES = {
    "mlp_small": [8, 16],
    "mlp_paper": [16, 32, 128],
    "cnn_tiny": [16, 32, 128],
    "lm_small": [8],
}
EVAL_BATCHES = {
    "mlp_small": 64,
    "mlp_paper": 256,
    "cnn_tiny": 128,
    "lm_small": 8,
}

# standalone kernel artifacts (HLO-path gossip/NAG, used by ablation
# benches; the coordinator's default path is the native rust implementation)
KERNEL_SIZES = [65536]

# (model, workers, per-worker batch) stacked train-step artifacts
STACKED_TRAIN = [
    ("mlp_small", 4, 8),
    ("mlp_paper", 4, 32),
    ("mlp_paper", 8, 16),
    ("cnn_tiny", 4, 32),
    ("lm_small", 4, 8),
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dt(d) -> str:
    return {"float32": "f32", "int32": "i32", "uint32": "u32"}[jnp.dtype(d).name]


def _spec(name, shape, dtype) -> dict:
    return {"name": name, "shape": [int(s) for s in shape], "dtype": _dt(dtype)}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_model_artifacts(cfg, out_dir: str, manifest: dict, verbose=True):
    named = cfg.init(0)
    pnames = [n for n, _ in named]
    pspecs = [_sds(a.shape, a.dtype) for _, a in named]
    x_dtype = jnp.int32 if isinstance(cfg, M.LmConfig) else jnp.float32

    manifest["models"][cfg.name] = {
        "params": [
            {"name": n, "shape": [int(s) for s in a.shape], "size": int(a.size)}
            for n, a in named
        ],
        "flat_size": M.flat_size(named),
        "data_shape": [int(s) for s in cfg.data_shape()],
        "x_dtype": _dt(x_dtype),
        "classes": int(getattr(cfg, "classes", getattr(cfg, "vocab", 0))),
        "kind": type(cfg).__name__,
    }

    def y_shape(b):
        return (b, cfg.seq) if isinstance(cfg, M.LmConfig) else (b,)

    for b in TRAIN_BATCHES[cfg.name]:
        fn = M.make_train_fn(cfg)
        args = pspecs + [
            _sds((b, *cfg.data_shape()), x_dtype),
            _sds(y_shape(b), jnp.int32),
            _sds((), jnp.int32),  # rng seed
        ]
        name = f"{cfg.name}_train_b{b}"
        if verbose:
            print(f"  lowering {name} ...", flush=True)
        text = to_hlo_text(jax.jit(fn, keep_unused=True).lower(*args))
        with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "kind": "train",
            "model": cfg.name,
            "batch": b,
            "inputs": [_spec(n, a.shape, a.dtype) for n, a in zip(pnames, pspecs)]
            + [
                _spec("x", (b, *cfg.data_shape()), x_dtype),
                _spec("y", y_shape(b), jnp.int32),
                _spec("seed", (), jnp.int32),
            ],
            "outputs": [_spec("loss", (), jnp.float32)]
            + [_spec(f"g_{n}", a.shape, a.dtype) for n, a in zip(pnames, pspecs)],
        }

    # stacked (vmapped-over-workers) train steps
    for (mname, w, b) in STACKED_TRAIN:
        if mname != cfg.name:
            continue
        fn = jax.vmap(M.make_train_fn(cfg))
        args = [_sds((w, *p.shape), p.dtype) for p in pspecs] + [
            _sds((w, b, *cfg.data_shape()), x_dtype),
            _sds((w, *y_shape(b)), jnp.int32),
            _sds((w,), jnp.int32),  # per-worker rng seed
        ]
        name = f"{cfg.name}_train_w{w}_b{b}"
        if verbose:
            print(f"  lowering {name} ...", flush=True)
        text = to_hlo_text(jax.jit(fn, keep_unused=True).lower(*args))
        with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "kind": "train_stacked",
            "model": cfg.name,
            "batch": b,
            "workers": w,
            "inputs": [
                _spec(n, (w, *a.shape), a.dtype) for n, a in zip(pnames, pspecs)
            ]
            + [
                _spec("x", (w, b, *cfg.data_shape()), x_dtype),
                _spec("y", (w, *y_shape(b)), jnp.int32),
                _spec("seed", (w,), jnp.int32),
            ],
            "outputs": [_spec("loss", (w,), jnp.float32)]
            + [_spec(f"g_{n}", (w, *a.shape), a.dtype) for n, a in zip(pnames, pspecs)],
        }

    b = EVAL_BATCHES[cfg.name]
    fn = M.make_eval_fn(cfg)
    args = pspecs + [
        _sds((b, *cfg.data_shape()), x_dtype),
        _sds(y_shape(b), jnp.int32),
        _sds((b,), jnp.float32),  # validity mask (handles ragged final batch)
    ]
    name = f"{cfg.name}_eval_b{b}"
    if verbose:
        print(f"  lowering {name} ...", flush=True)
    text = to_hlo_text(jax.jit(fn, keep_unused=True).lower(*args))
    with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
        f.write(text)
    manifest["artifacts"][name] = {
        "file": f"{name}.hlo.txt",
        "kind": "eval",
        "model": cfg.name,
        "batch": b,
        "inputs": [_spec(n, a.shape, a.dtype) for n, a in zip(pnames, pspecs)]
        + [
            _spec("x", (b, *cfg.data_shape()), x_dtype),
            _spec("y", y_shape(b), jnp.int32),
            _spec("mask", (b,), jnp.float32),
        ],
        "outputs": [
            _spec("sum_loss", (), jnp.float32),
            _spec("num_correct", (), jnp.float32),
        ],
    }


def lower_kernel_artifacts(out_dir: str, manifest: dict, sizes, verbose=True):
    for n in sizes:
        vec = _sds((n,), jnp.float32)
        scal = _sds((), jnp.float32)

        name = f"gossip_pair_n{n}"
        if verbose:
            print(f"  lowering {name} ...", flush=True)
        text = to_hlo_text(
            jax.jit(lambda ti, tk, a: elastic_pair_update(ti, tk, a)).lower(
                vec, vec, scal
            )
        )
        with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "kind": "gossip",
            "model": None,
            "batch": n,
            "inputs": [
                _spec("theta_i", (n,), jnp.float32),
                _spec("theta_k", (n,), jnp.float32),
                _spec("alpha", (), jnp.float32),
            ],
            "outputs": [
                _spec("theta_i_out", (n,), jnp.float32),
                _spec("theta_k_out", (n,), jnp.float32),
            ],
        }

        name = f"nag_n{n}"
        if verbose:
            print(f"  lowering {name} ...", flush=True)
        text = to_hlo_text(
            jax.jit(
                lambda t, v, g, eta, mu: nag_update(t, v, g, eta, mu)
            ).lower(vec, vec, vec, scal, scal)
        )
        with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "kind": "nag",
            "model": None,
            "batch": n,
            "inputs": [
                _spec("theta", (n,), jnp.float32),
                _spec("v", (n,), jnp.float32),
                _spec("g", (n,), jnp.float32),
                _spec("eta", (), jnp.float32),
                _spec("mu", (), jnp.float32),
            ],
            "outputs": [
                _spec("theta_out", (n,), jnp.float32),
                _spec("v_out", (n,), jnp.float32),
            ],
        }


def write_fixtures(out_dir: str):
    """Golden outputs for rust cross-engine agreement tests (mlp_small)."""
    cfg = M.registry()["mlp_small"]
    named = cfg.init(0)
    params = tuple(a for _, a in named)
    b = TRAIN_BATCHES["mlp_small"][0]
    rng = np.random.RandomState(1234)
    x = jnp.asarray(rng.randn(b, cfg.in_dim).astype(np.float32))
    y = jnp.asarray(rng.randint(0, cfg.classes, size=b).astype(np.int32))
    seed = jnp.int32(7)
    out = M.make_train_fn(cfg)(*params, x, y, seed)
    loss = float(out[0])
    g0 = np.asarray(out[1])

    # gossip kernel golden
    n = KERNEL_SIZES[0]
    ti = jnp.asarray(rng.randn(n).astype(np.float32))
    tk = jnp.asarray(rng.randn(n).astype(np.float32))
    gi, gk = elastic_pair_update(ti, tk, jnp.float32(0.5))

    fixtures = {
        "mlp_small_train": {
            "batch": b,
            "x": np.asarray(x).reshape(-1).tolist(),
            "y": np.asarray(y).tolist(),
            "seed": 7,
            "loss": loss,
            "g0_sum": float(np.sum(g0)),
            "g0_abs_sum": float(np.sum(np.abs(g0))),
        },
        "gossip_pair": {
            "n": n,
            "alpha": 0.5,
            "ti_head": np.asarray(ti[:8]).tolist(),
            "tk_head": np.asarray(tk[:8]).tolist(),
            "gi_head": np.asarray(gi[:8]).tolist(),
            "gk_head": np.asarray(gk[:8]).tolist(),
            "gi_sum": float(jnp.sum(gi)),
            "gk_sum": float(jnp.sum(gk)),
        },
    }
    with open(os.path.join(out_dir, "fixtures.json"), "w") as f:
        json.dump(fixtures, f, indent=1)


def save_init_params(out_dir: str, manifest: dict):
    """Serialize each model's seed-0 initial parameters as raw f32 .bin.

    The paper initializes every worker from the same seed (Table 4.1
    caption); the rust side can also re-derive inits itself, but shipping
    the jax Kaiming init keeps parity with the paper's §4.1 recipe.
    """
    for name, cfg in M.registry().items():
        named = cfg.init(0)
        flat = np.concatenate([np.asarray(a).reshape(-1) for _, a in named])
        path = os.path.join(out_dir, f"{name}_init.bin")
        flat.astype("<f4").tofile(path)
        manifest["models"][name]["init_file"] = f"{name}_init.bin"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="model-name prefix filter")
    ap.add_argument("--skip-kernels", action="store_true")
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    manifest = {"version": 1, "models": {}, "artifacts": {}}

    for name, cfg in M.registry().items():
        if args.only and not name.startswith(args.only):
            continue
        print(f"[aot] model {name}", flush=True)
        lower_model_artifacts(cfg, args.out, manifest)

    if not args.skip_kernels:
        print("[aot] kernels", flush=True)
        lower_kernel_artifacts(args.out, manifest, KERNEL_SIZES)

    save_init_params(args.out, manifest)
    write_fixtures(args.out)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    n_art = len(manifest["artifacts"])
    print(f"[aot] wrote {n_art} artifacts + manifest.json to {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
