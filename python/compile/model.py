"""Layer-2 JAX models: the paper's workloads, built on the Layer-1 kernels.

Three model families, matching DESIGN.md §4/§5:

* :class:`MlpConfig` — the §4.1 permutation-invariant MNIST MLP
  (784-1024-1024-1024-10, dropout 0.2/0.5, ReLU, Kaiming init), with the
  hidden width configurable so tests can run a small variant.
* :class:`CnnConfig` — TinyResNet, the documented substitution for the
  §4.2 pre-activation ResNet-18 (same ingredients — pre-activation
  residual units + batch normalization — at CPU-tractable size).
* :class:`LmConfig` — a small GPT-style byte LM for the end-to-end
  training driver mandated by the reproduction harness.

Every model exposes the same contract consumed by :mod:`compile.aot`:

* ``init(seed) -> list[(name, jnp.ndarray)]``   (ordered parameter list)
* ``train_step(params_tuple, x, y, seed) -> (loss, grads_tuple)``
* ``eval_step(params_tuple, x, y, mask) -> (sum_loss, num_correct)``

``params`` is always a *tuple of arrays in init order* — jax flattens
tuples in order, so the HLO entry-computation parameter order is exactly
(params..., data...), which is the convention the rust runtime relies on
(recorded per-artifact in ``manifest.json``).

Dropout / any randomness takes an ``int32 seed`` scalar input (a traced
``jax.random.PRNGKey(seed)`` lowers fine) so the rust side controls RNG.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.dense import dense

Params = Tuple[jax.Array, ...]
NamedParams = List[Tuple[str, jax.Array]]


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-example softmax cross-entropy. ``labels``: int32 ``(B,)``."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return logz - gold


def _kaiming(key, fan_in: int, shape) -> jax.Array:
    """He-normal init (the paper's §4.1 'Kaiming-initialization')."""
    std = math.sqrt(2.0 / fan_in)
    return std * jax.random.normal(key, shape, jnp.float32)


def _dropout(x: jax.Array, rate: float, key) -> jax.Array:
    keep = 1.0 - rate
    mask = jax.random.bernoulli(key, keep, x.shape)
    return jnp.where(mask, x / keep, 0.0)


def _accuracy_pieces(logits, y, mask):
    """(masked summed loss, masked correct count) as f32 scalars."""
    per = softmax_xent(logits, y)
    correct = (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32)
    return jnp.sum(per * mask), jnp.sum(correct * mask)


# ---------------------------------------------------------------------------
# MLP — §4.1 MNIST workload
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MlpConfig:
    name: str = "mlp_paper"
    in_dim: int = 784
    hidden: int = 1024
    depth: int = 3  # number of hidden layers
    classes: int = 10
    p_in: float = 0.2  # input dropout (Srivastava et al., 2014)
    p_hidden: float = 0.5  # hidden dropout

    def layer_dims(self) -> List[Tuple[int, int]]:
        dims = [self.in_dim] + [self.hidden] * self.depth + [self.classes]
        return list(zip(dims[:-1], dims[1:]))

    def init(self, seed: int = 0) -> NamedParams:
        key = jax.random.PRNGKey(seed)
        out: NamedParams = []
        for li, (fin, fout) in enumerate(self.layer_dims()):
            key, kw = jax.random.split(key)
            out.append((f"w{li}", _kaiming(kw, fin, (fin, fout))))
            out.append((f"b{li}", jnp.zeros((fout,), jnp.float32)))
        return out

    def apply(self, params: Params, x: jax.Array, seed, train: bool) -> jax.Array:
        """Forward pass; ``x: (B, in_dim)`` f32. Uses the Pallas dense kernel."""
        n_layers = len(self.layer_dims())
        key = jax.random.PRNGKey(seed) if train else None
        h = x
        if train and self.p_in > 0:
            key, k = jax.random.split(key)
            h = _dropout(h, self.p_in, k)
        for li in range(n_layers):
            w, b = params[2 * li], params[2 * li + 1]
            last = li == n_layers - 1
            h = dense(h, w, b, not last)
            if train and not last and self.p_hidden > 0:
                key, k = jax.random.split(key)
                h = _dropout(h, self.p_hidden, k)
        return h

    def loss(self, params: Params, x, y, seed) -> jax.Array:
        logits = self.apply(params, x, seed, train=True)
        return jnp.mean(softmax_xent(logits, y))

    def train_step(self):
        def step(params: Params, x, y, seed):
            loss, grads = jax.value_and_grad(self.loss)(params, x, y, seed)
            return (loss, *grads)

        return step

    def eval_step(self):
        def step(params: Params, x, y, mask):
            logits = self.apply(params, x, 0, train=False)
            return _accuracy_pieces(logits, y, mask)

        return step

    def data_shape(self):
        return (self.in_dim,)


# ---------------------------------------------------------------------------
# TinyResNet — §4.2 CIFAR workload (documented ResNet-18 substitution)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CnnConfig:
    name: str = "cnn_tiny"
    in_hw: int = 32
    in_ch: int = 3
    stages: Tuple[int, ...] = (16, 32, 64)  # channels per stage
    blocks_per_stage: int = 1
    classes: int = 10

    # --- parameter construction -------------------------------------------------
    def init(self, seed: int = 0) -> NamedParams:
        key = jax.random.PRNGKey(seed)
        out: NamedParams = []

        def conv(name, kh, kw, cin, cout):
            nonlocal key
            key, k = jax.random.split(key)
            out.append((name, _kaiming(k, kh * kw * cin, (kh, kw, cin, cout))))

        def bn(name, ch):
            out.append((f"{name}_scale", jnp.ones((ch,), jnp.float32)))
            out.append((f"{name}_bias", jnp.zeros((ch,), jnp.float32)))

        conv("stem", 3, 3, self.in_ch, self.stages[0])
        cin = self.stages[0]
        for si, ch in enumerate(self.stages):
            for bi in range(self.blocks_per_stage):
                pre = f"s{si}b{bi}"
                bn(f"{pre}_bn1", cin)
                conv(f"{pre}_conv1", 3, 3, cin, ch)
                bn(f"{pre}_bn2", ch)
                conv(f"{pre}_conv2", 3, 3, ch, ch)
                if cin != ch:
                    conv(f"{pre}_proj", 1, 1, cin, ch)
                cin = ch
        bn("head_bn", cin)
        key, k = jax.random.split(key)
        out.append(("head_w", _kaiming(k, cin, (cin, self.classes))))
        out.append(("head_b", jnp.zeros((self.classes,), jnp.float32)))
        return out

    # --- forward ------------------------------------------------------------------
    def apply(self, params: Params, x: jax.Array, seed, train: bool) -> jax.Array:
        """``x: (B, H, W, C)`` NHWC f32.

        Batch norm uses batch statistics at both train and eval time (no
        running averages — a deliberate, documented simplification: the
        functional train-step artifact carries no mutable state).
        """
        del seed, train
        names = [n for n, _ in self.init(0)]
        p = dict(zip(names, params))

        def conv2d(h, w, stride=1):
            return jax.lax.conv_general_dilated(
                h, w, (stride, stride), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            )

        def batchnorm(h, pre):
            mean = jnp.mean(h, axis=(0, 1, 2), keepdims=True)
            var = jnp.var(h, axis=(0, 1, 2), keepdims=True)
            hn = (h - mean) * jax.lax.rsqrt(var + 1e-5)
            return hn * p[f"{pre}_scale"] + p[f"{pre}_bias"]

        h = conv2d(x, p["stem"])
        cin = self.stages[0]
        for si, ch in enumerate(self.stages):
            for bi in range(self.blocks_per_stage):
                pre = f"s{si}b{bi}"
                stride = 2 if (si > 0 and bi == 0) else 1
                # pre-activation residual unit (He et al., 2016b)
                z = jax.nn.relu(batchnorm(h, f"{pre}_bn1"))
                shortcut = h
                if cin != ch:
                    shortcut = conv2d(z, p[f"{pre}_proj"], stride)
                elif stride != 1:
                    shortcut = h[:, ::stride, ::stride, :]
                z = conv2d(z, p[f"{pre}_conv1"], stride)
                z = jax.nn.relu(batchnorm(z, f"{pre}_bn2"))
                z = conv2d(z, p[f"{pre}_conv2"])
                h = z + shortcut
                cin = ch
        h = jax.nn.relu(batchnorm(h, "head_bn"))
        h = jnp.mean(h, axis=(1, 2))  # global average pool -> (B, C)
        return dense(h, p["head_w"], p["head_b"], False)

    def loss(self, params: Params, x, y, seed):
        logits = self.apply(params, x, seed, train=True)
        return jnp.mean(softmax_xent(logits, y))

    def train_step(self):
        def step(params: Params, x, y, seed):
            loss, grads = jax.value_and_grad(self.loss)(params, x, y, seed)
            return (loss, *grads)

        return step

    def eval_step(self):
        def step(params: Params, x, y, mask):
            logits = self.apply(params, x, 0, train=False)
            return _accuracy_pieces(logits, y, mask)

        return step

    def data_shape(self):
        return (self.in_hw, self.in_hw, self.in_ch)


# ---------------------------------------------------------------------------
# Transformer LM — end-to-end driver workload
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LmConfig:
    name: str = "lm_small"
    vocab: int = 256
    seq: int = 64
    d_model: int = 128
    n_head: int = 4
    n_layer: int = 2
    d_ff: int = 512

    def init(self, seed: int = 0) -> NamedParams:
        key = jax.random.PRNGKey(seed)
        out: NamedParams = []

        def mat(name, fan_in, shape):
            nonlocal key
            key, k = jax.random.split(key)
            out.append((name, _kaiming(k, fan_in, shape)))

        d = self.d_model
        mat("tok_emb", d, (self.vocab, d))
        mat("pos_emb", d, (self.seq, d))
        for li in range(self.n_layer):
            pre = f"l{li}"
            out.append((f"{pre}_ln1_scale", jnp.ones((d,), jnp.float32)))
            out.append((f"{pre}_ln1_bias", jnp.zeros((d,), jnp.float32)))
            mat(f"{pre}_wq", d, (d, d))
            mat(f"{pre}_wk", d, (d, d))
            mat(f"{pre}_wv", d, (d, d))
            mat(f"{pre}_wo", d, (d, d))
            out.append((f"{pre}_ln2_scale", jnp.ones((d,), jnp.float32)))
            out.append((f"{pre}_ln2_bias", jnp.zeros((d,), jnp.float32)))
            mat(f"{pre}_ff1_w", d, (d, self.d_ff))
            out.append((f"{pre}_ff1_b", jnp.zeros((self.d_ff,), jnp.float32)))
            mat(f"{pre}_ff2_w", self.d_ff, (self.d_ff, d))
            out.append((f"{pre}_ff2_b", jnp.zeros((d,), jnp.float32)))
        out.append(("lnf_scale", jnp.ones((d,), jnp.float32)))
        out.append(("lnf_bias", jnp.zeros((d,), jnp.float32)))
        mat("head_w", d, (d, self.vocab))
        out.append(("head_b", jnp.zeros((self.vocab,), jnp.float32)))
        return out

    def apply(self, params: Params, tokens: jax.Array) -> jax.Array:
        """``tokens: (B, S)`` int32 → logits ``(B, S, vocab)``."""
        names = [n for n, _ in self.init(0)]
        p = dict(zip(names, params))
        b, s = tokens.shape
        d, nh = self.d_model, self.n_head
        hd = d // nh

        def layernorm(h, scale, bias):
            mu = jnp.mean(h, axis=-1, keepdims=True)
            var = jnp.var(h, axis=-1, keepdims=True)
            return (h - mu) * jax.lax.rsqrt(var + 1e-5) * scale + bias

        h = p["tok_emb"][tokens] + p["pos_emb"][None, :s, :]
        causal = jnp.tril(jnp.ones((s, s), jnp.bool_))
        for li in range(self.n_layer):
            pre = f"l{li}"
            z = layernorm(h, p[f"{pre}_ln1_scale"], p[f"{pre}_ln1_bias"])
            z2 = z.reshape(b * s, d)
            q = (z2 @ p[f"{pre}_wq"]).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
            k = (z2 @ p[f"{pre}_wk"]).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
            v = (z2 @ p[f"{pre}_wv"]).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
            att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(hd)
            att = jnp.where(causal[None, None], att, -1e30)
            att = jax.nn.softmax(att, axis=-1)
            o = jnp.einsum("bhqk,bhkd->bhqd", att, v)
            o = o.transpose(0, 2, 1, 3).reshape(b * s, d) @ p[f"{pre}_wo"]
            h = h + o.reshape(b, s, d)
            z = layernorm(h, p[f"{pre}_ln2_scale"], p[f"{pre}_ln2_bias"])
            # MLP block via the Layer-1 fused dense kernel
            z2 = dense(z.reshape(b * s, d), p[f"{pre}_ff1_w"], p[f"{pre}_ff1_b"], True)
            z2 = dense(z2, p[f"{pre}_ff2_w"], p[f"{pre}_ff2_b"], False)
            h = h + z2.reshape(b, s, d)
        h = layernorm(h, p["lnf_scale"], p["lnf_bias"])
        logits = dense(h.reshape(b * s, d), p["head_w"], p["head_b"], False)
        return logits.reshape(b, s, self.vocab)

    def loss(self, params: Params, tokens, targets, seed):
        del seed
        logits = self.apply(params, tokens)
        per = softmax_xent(
            logits.reshape(-1, self.vocab), targets.reshape(-1)
        )
        return jnp.mean(per)

    def train_step(self):
        def step(params: Params, x, y, seed):
            loss, grads = jax.value_and_grad(self.loss)(params, x, y, seed)
            return (loss, *grads)

        return step

    def eval_step(self):
        def step(params: Params, x, y, mask):
            logits = self.apply(params, x)
            per = softmax_xent(logits.reshape(-1, self.vocab), y.reshape(-1))
            m = jnp.repeat(mask, x.shape[1])
            correct = (
                jnp.argmax(logits.reshape(-1, self.vocab), axis=-1) == y.reshape(-1)
            ).astype(jnp.float32)
            return jnp.sum(per * m), jnp.sum(correct * m)

        return step

    def data_shape(self):
        return (self.seq,)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

ModelConfig = MlpConfig | CnnConfig | LmConfig


def registry() -> dict:
    """Named model configurations lowered by aot.py."""
    return {
        # fast variant for rust integration tests / CI
        "mlp_small": MlpConfig(name="mlp_small", in_dim=64, hidden=64, depth=2),
        # the paper's §4.1 architecture
        "mlp_paper": MlpConfig(name="mlp_paper"),
        # §4.2 TinyResNet substitution
        "cnn_tiny": CnnConfig(name="cnn_tiny"),
        # e2e LM driver
        "lm_small": LmConfig(name="lm_small"),
    }


def flat_size(named: NamedParams) -> int:
    return sum(int(a.size) for _, a in named)


def make_train_fn(cfg: ModelConfig) -> Callable:
    """(params..., x, y, seed) flat-positional train step for lowering."""
    n_params = len(cfg.init(0))
    step = cfg.train_step()

    def fn(*args):
        params = tuple(args[:n_params])
        x, y, seed = args[n_params:]
        return step(params, x, y, seed)

    return fn


def make_eval_fn(cfg: ModelConfig) -> Callable:
    n_params = len(cfg.init(0))
    step = cfg.eval_step()

    def fn(*args):
        params = tuple(args[:n_params])
        x, y, mask = args[n_params:]
        return step(params, x, y, mask)

    return fn
