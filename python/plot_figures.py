#!/usr/bin/env python
"""Render the paper's figures from the harness CSV series.

Offline plotting utility (NOT part of the training path): consumes the
`epoch,...,val_acc_mean,val_acc_min,val_acc_max,...` CSVs written by
`repro table` / `repro figure` and draws the thesis's mean ± range bands
(solid line + shaded region, Figures 4.1-4.4 style).

Usage:
    python python/plot_figures.py results/table_4_1 -o results/fig_4_3.png
    python python/plot_figures.py results/figure_4_1 -o results/fig_4_1.png --metric train_loss
"""

from __future__ import annotations

import argparse
import csv
import os
import sys


def load_series(path: str) -> dict:
    cols: dict[str, list[float]] = {}
    with open(path) as f:
        for row in csv.DictReader(f):
            for k, v in row.items():
                cols.setdefault(k, []).append(float(v))
    return cols


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("dir", help="directory of curve CSVs (one per experiment)")
    ap.add_argument("-o", "--out", default=None, help="output image (default <dir>/figure.png)")
    ap.add_argument("--metric", default="val_acc", choices=["val_acc", "train_loss", "aggregate_acc"])
    ap.add_argument("--only", default=None, help="comma-separated label substrings to include")
    args = ap.parse_args(argv)

    import matplotlib

    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    files = sorted(f for f in os.listdir(args.dir) if f.endswith(".csv"))
    if args.only:
        keys = args.only.split(",")
        files = [f for f in files if any(k in f for k in keys)]
    if not files:
        print(f"no CSVs in {args.dir}", file=sys.stderr)
        return 1

    fig, ax = plt.subplots(figsize=(8, 5))
    for f in files:
        label = f[:-4]
        s = load_series(os.path.join(args.dir, f))
        x = s["epoch"]
        # blue-ish for EG, red-ish for GS, grey otherwise — the thesis's
        # Figure 4.3 color convention
        color = None
        if label.startswith("EG"):
            color = "tab:blue"
        elif label.startswith("GS"):
            color = "tab:red"
        if args.metric == "val_acc":
            (line,) = ax.plot(x, s["val_acc_mean"], label=label, color=color, alpha=0.9)
            ax.fill_between(x, s["val_acc_min"], s["val_acc_max"], color=line.get_color(), alpha=0.15)
            ax.set_ylabel("validation accuracy (mean ± range across workers)")
        else:
            col = "train_loss" if args.metric == "train_loss" else "aggregate_acc"
            ax.plot(x, s[col], label=label, color=color, alpha=0.9)
            ax.set_ylabel(args.metric)
    ax.set_xlabel("epoch")
    ax.legend(fontsize=7, ncols=2)
    ax.grid(alpha=0.3)
    out = args.out or os.path.join(args.dir, "figure.png")
    fig.tight_layout()
    fig.savefig(out, dpi=140)
    print(f"wrote {out} ({len(files)} series)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
