"""Layer-1 kernel correctness: Pallas vs pure-jnp oracle.

Hypothesis sweeps shapes (including awkward non-tile-multiple sizes) and
value ranges; every kernel must match its ref.py oracle to tight f32
tolerance.  This is the core correctness signal for the compute layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dense
from compile.kernels.dense import matmul, dense as dense_fn, relu_mask_mul
D = dense
from compile.kernels import gossip as G
from compile.kernels import optim as O
from compile.kernels import ref as R

jax.config.update("jax_enable_x64", False)

dims = st.integers(min_value=1, max_value=200)
small_f = st.floats(min_value=-3.0, max_value=3.0, allow_nan=False, width=32)


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, m, k), _rand(rng, k, n)
    np.testing.assert_allclose(
        D.matmul(x, w), R.matmul_ref(x, w), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("m,k,n", [(128, 1024, 1024), (32, 784, 1024), (1, 1, 1)])
def test_matmul_paper_shapes(m, k, n):
    rng = np.random.default_rng(0)
    x, w = _rand(rng, m, k), _rand(rng, k, n)
    np.testing.assert_allclose(
        D.matmul(x, w), R.matmul_ref(x, w), rtol=1e-4, atol=1e-4
    )


# ---------------------------------------------------------------------------
# fused dense fwd
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, relu=st.booleans(), seed=st.integers(0, 2**31 - 1))
def test_dense_fwd_matches_ref(m, k, n, relu, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, m, k), _rand(rng, k, n)
    b = _rand(rng, n)
    np.testing.assert_allclose(
        D.dense(x, w, b, relu), R.dense_ref(x, w, b, relu), rtol=1e-4, atol=1e-4
    )


def test_dense_zero_rows_exact():
    # padding rows must not leak into real outputs
    x = jnp.zeros((3, 5))
    w = jnp.ones((5, 7))
    b = jnp.full((7,), -1.0)
    out = D.dense(x, w, b, True)
    assert out.shape == (3, 7)
    np.testing.assert_array_equal(np.asarray(out), 0.0)  # relu(-1) = 0


# ---------------------------------------------------------------------------
# dense custom_vjp
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 48),
    k=st.integers(1, 48),
    n=st.integers(1, 48),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_vjp_matches_ref(m, k, n, relu, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, m, k), _rand(rng, k, n)
    b, dy = _rand(rng, n), _rand(rng, m, n)

    _, vjp = jax.vjp(lambda x_, w_, b_: D.dense(x_, w_, b_, relu), x, w, b)
    dx, dw, db = vjp(dy)
    rx, rw, rb = R.dense_grads_ref(x, w, b, dy, relu)
    np.testing.assert_allclose(dx, rx, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(dw, rw, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(db, rb, rtol=1e-4, atol=1e-4)


def test_dense_grad_finite_difference():
    rng = np.random.default_rng(3)
    x, w = _rand(rng, 4, 6), _rand(rng, 6, 5)
    b = _rand(rng, 5)

    def f(w_):
        return jnp.sum(D.dense(x, w_, b, True) ** 2)

    g = jax.grad(f)(w)
    eps = 1e-3
    i, j = 2, 3
    wp = w.at[i, j].add(eps)
    wm = w.at[i, j].add(-eps)
    fd = (f(wp) - f(wm)) / (2 * eps)
    np.testing.assert_allclose(g[i, j], fd, rtol=2e-2, atol=2e-3)


# ---------------------------------------------------------------------------
# relu mask
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(m=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_relu_mask_matches_ref(m, n, seed):
    rng = np.random.default_rng(seed)
    dy, out = _rand(rng, m, n), _rand(rng, m, n)
    np.testing.assert_allclose(
        D.relu_mask_mul(dy, out), R.relu_mask_mul_ref(dy, out), rtol=0, atol=0
    )


# ---------------------------------------------------------------------------
# elastic pair update
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 5000),
    alpha=st.floats(0.0, 1.0, allow_nan=False, width=32),
    seed=st.integers(0, 2**31 - 1),
)
def test_gossip_pair_matches_ref(n, alpha, seed):
    rng = np.random.default_rng(seed)
    ti, tk = _rand(rng, n), _rand(rng, n)
    gi, gk = G.elastic_pair_update(ti, tk, jnp.float32(alpha))
    ri, rk = R.elastic_pair_update_ref(ti, tk, alpha)
    np.testing.assert_allclose(gi, ri, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(gk, rk, rtol=1e-6, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 5000),
    alpha=st.floats(0.0, 1.0, allow_nan=False, width=32),
    seed=st.integers(0, 2**31 - 1),
)
def test_gossip_elastic_symmetry_conserved(n, alpha, seed):
    """theta_i' + theta_k' ~= theta_i + theta_k to f32 rounding.

    The kernel computes delta once and applies ±delta (elastic symmetry:
    the same quantity leaves i and enters k), so the pairwise sum is
    conserved up to one rounding of each add.
    """
    rng = np.random.default_rng(seed)
    ti, tk = _rand(rng, n), _rand(rng, n)
    gi, gk = G.elastic_pair_update(ti, tk, jnp.float32(alpha))
    before = np.asarray(ti) + np.asarray(tk)
    after = np.asarray(gi) + np.asarray(gk)
    np.testing.assert_allclose(after, before, rtol=1e-6, atol=1e-6)


def test_gossip_alpha_extremes():
    """Eq. 3.9: alpha=0 no-op; alpha=1 swap; alpha=0.5 averages."""
    rng = np.random.default_rng(0)
    ti, tk = _rand(rng, 300), _rand(rng, 300)
    gi, gk = G.elastic_pair_update(ti, tk, jnp.float32(0.0))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(ti))
    np.testing.assert_array_equal(np.asarray(gk), np.asarray(tk))
    gi, gk = G.elastic_pair_update(ti, tk, jnp.float32(1.0))
    np.testing.assert_allclose(gi, tk, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(gk, ti, rtol=1e-5, atol=1e-6)
    gi, gk = G.elastic_pair_update(ti, tk, jnp.float32(0.5))
    np.testing.assert_allclose(gi, (ti + tk) / 2, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(gk, (ti + tk) / 2, rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# NAG update
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 5000),
    eta=st.floats(2**-14, 0.5, allow_nan=False, width=32),
    mu=st.floats(0.0, 0.99609375, allow_nan=False, width=32),
    seed=st.integers(0, 2**31 - 1),
)
def test_nag_matches_ref(n, eta, mu, seed):
    rng = np.random.default_rng(seed)
    t, v, g = _rand(rng, n), _rand(rng, n), _rand(rng, n)
    ot, ov = O.nag_update(t, v, g, jnp.float32(eta), jnp.float32(mu))
    rt, rv = R.nag_update_ref(t, v, g, eta, mu)
    np.testing.assert_allclose(ot, rt, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(ov, rv, rtol=1e-5, atol=1e-6)


def test_nag_zero_momentum_is_sgd():
    rng = np.random.default_rng(1)
    t, v, g = _rand(rng, 100), _rand(rng, 100), _rand(rng, 100)
    ot, ov = O.nag_update(t, v, g, jnp.float32(0.1), jnp.float32(0.0))
    np.testing.assert_allclose(ot, t - 0.1 * g, rtol=1e-6)
    np.testing.assert_allclose(ov, -0.1 * g, rtol=1e-6)
