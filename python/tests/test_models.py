"""Layer-2 model contracts: shapes, grads, loss sanity, determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def mlp():
    return M.MlpConfig(name="t", in_dim=32, hidden=24, depth=2, classes=10)


def _params(cfg):
    return tuple(a for _, a in cfg.init(0))


def test_mlp_param_layout(mlp):
    named = mlp.init(0)
    names = [n for n, _ in named]
    # depth=2 hidden layers + output = 3 (w, b) pairs
    assert names == ["w0", "b0", "w1", "b1", "w2", "b2"]
    assert named[0][1].shape == (32, 24)
    assert named[-2][1].shape == (24, 10)
    assert M.flat_size(named) == 32 * 24 + 24 + 24 * 24 + 24 + 24 * 10 + 10


def test_mlp_forward_shape_and_eval_determinism(mlp):
    p = _params(mlp)
    x = jnp.ones((5, 32))
    a = mlp.apply(p, x, 0, train=False)
    b = mlp.apply(p, x, 123, train=False)  # seed ignored at eval
    assert a.shape == (5, 10)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mlp_dropout_seed_controls_randomness(mlp):
    p = _params(mlp)
    x = jnp.ones((5, 32))
    a = mlp.apply(p, x, 1, train=True)
    b = mlp.apply(p, x, 1, train=True)
    c = mlp.apply(p, x, 2, train=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_mlp_train_step_outputs(mlp):
    p = _params(mlp)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((8, 32), dtype=np.float32))
    y = jnp.asarray(rng.integers(0, 10, 8).astype(np.int32))
    out = M.make_train_fn(mlp)(*p, x, y, jnp.int32(3))
    assert len(out) == 1 + len(p)
    loss = float(out[0])
    assert 0.0 < loss < 20.0
    for g, pp in zip(out[1:], p):
        assert g.shape == pp.shape
        assert np.isfinite(np.asarray(g)).all()


def test_mlp_loss_decreases_under_sgd(mlp):
    p = list(_params(mlp))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((32, 32), dtype=np.float32))
    y = jnp.asarray(rng.integers(0, 10, 32).astype(np.int32))
    step = jax.jit(M.make_train_fn(mlp))
    first = None
    for it in range(30):
        out = step(*p, x, y, jnp.int32(it))
        if first is None:
            first = float(out[0])
        p = [pp - 0.05 * g for pp, g in zip(p, out[1:])]
    assert float(out[0]) < first * 0.7


def test_mlp_eval_mask(mlp):
    p = _params(mlp)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((6, 32), dtype=np.float32))
    y = jnp.asarray(rng.integers(0, 10, 6).astype(np.int32))
    full = M.make_eval_fn(mlp)(*p, x, y, jnp.ones(6))
    half = M.make_eval_fn(mlp)(*p, x, y, jnp.asarray([1, 1, 1, 0, 0, 0], jnp.float32))
    assert float(half[0]) <= float(full[0]) + 1e-5
    assert float(half[1]) <= float(full[1])
    # masked rows contribute nothing: recompute on the first 3 rows only
    sub = M.make_eval_fn(
        M.MlpConfig(name="t", in_dim=32, hidden=24, depth=2, classes=10)
    )
    # (same cfg; mask semantics checked via sum equality)
    manual = M.make_eval_fn(mlp)(*p, x, y, jnp.asarray([1, 1, 1, 0, 0, 0], jnp.float32))
    np.testing.assert_allclose(float(half[0]), float(manual[0]), rtol=1e-6)


def test_cnn_shapes_and_grads():
    cfg = M.CnnConfig(name="t", in_hw=16, stages=(8, 16), blocks_per_stage=1)
    p = _params(cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 16, 16, 3), dtype=np.float32))
    y = jnp.asarray(rng.integers(0, 10, 4).astype(np.int32))
    logits = cfg.apply(p, x, 0, train=False)
    assert logits.shape == (4, 10)
    out = M.make_train_fn(cfg)(*p, x, y, jnp.int32(0))
    assert len(out) == 1 + len(p)
    assert np.isfinite(float(out[0]))


def test_cnn_residual_projection_param_names():
    cfg = M.CnnConfig(name="t", in_hw=16, stages=(8, 16), blocks_per_stage=1)
    names = [n for n, _ in cfg.init(0)]
    assert "s1b0_proj" in names  # channel change 8->16 requires projection
    assert "s0b0_proj" not in names  # stem already outputs 8 channels


def test_lm_shapes_and_loss():
    cfg = M.LmConfig(name="t", vocab=50, seq=12, d_model=16, n_head=2, n_layer=1, d_ff=32)
    p = _params(cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 50, (3, 12)).astype(np.int32))
    logits = cfg.apply(p, x)
    assert logits.shape == (3, 12, 50)
    loss = cfg.loss(p, x, x, 0)
    # untrained loss ~= ln(vocab)
    assert abs(float(loss) - np.log(50)) < 1.5


def test_lm_causality():
    """Changing a future token must not affect earlier logits."""
    cfg = M.LmConfig(name="t", vocab=50, seq=8, d_model=16, n_head=2, n_layer=1, d_ff=32)
    p = _params(cfg)
    x1 = jnp.asarray(np.arange(8, dtype=np.int32)[None, :] % 50)
    x2 = x1.at[0, 7].set(42)
    l1 = cfg.apply(p, x1)
    l2 = cfg.apply(p, x2)
    np.testing.assert_allclose(
        np.asarray(l1[0, :7]), np.asarray(l2[0, :7]), rtol=1e-5, atol=1e-6
    )


def test_registry_flat_sizes_positive():
    for name, cfg in M.registry().items():
        if name == "mlp_paper":
            # paper arch: 784*1024 + 1024 + 2*(1024^2+1024) + 1024*10 + 10
            assert M.flat_size(cfg.init(0)) == (
                784 * 1024 + 1024 + 2 * (1024 * 1024 + 1024) + 1024 * 10 + 10
            )


def test_softmax_xent_matches_manual():
    logits = jnp.asarray([[1.0, 2.0, 3.0], [0.0, 0.0, 0.0]])
    y = jnp.asarray([2, 0], jnp.int32)
    per = M.softmax_xent(logits, y)
    manual0 = -np.log(np.exp(3) / np.exp([1, 2, 3]).sum())
    np.testing.assert_allclose(float(per[0]), manual0, rtol=1e-6)
    np.testing.assert_allclose(float(per[1]), np.log(3), rtol=1e-6)
